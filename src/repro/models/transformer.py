"""Decoder-only / encoder-decoder / cross-attention transformer LM.

One flexible implementation drives 8 of the 10 assigned architectures
(dense, MoE, SWA, qk-norm, QKV-bias, whisper enc-dec, llama-vision
cross-attn); mamba2/zamba2 live in mamba2.py / hybrid.py.

Structure: pre-norm blocks, `lax.scan` over stacked layer params
(leading L dim on every leaf) with configurable remat.  Enc-dec models
(whisper) carry an ``xattn`` sub-block inside every decoder layer
(self-attn → cross-attn → MLP, whisper order); VLM models (llama-3.2-
vision) interleave dedicated cross-attention layers (with their own MLP,
llama-3.2 style) every ``cross_attn_every`` self layers.

The LM head is the paper's Bayesian weight-decomposition layer (µ, ρ) —
trained with Bayes-by-backprop, served with CLT-GRNG sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bayes_layer
from repro.core.bayes_layer import BayesDenseConfig
from repro.core.clt_grng import GRNGConfig
from repro.core.lfsr import indexed_selections
from repro.models import attention as attn
from repro.models import blocks
from repro.models.moe import init_moe, moe_apply


# ----------------------------------------------------------------------
# Config
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    vocab_pad_multiple: int = 256
    norm: str = "rms"            # rms | ln
    mlp: str = "swiglu"          # swiglu | gelu
    use_rope: bool = True
    rope_theta: float = 1e6
    qk_norm: bool = False
    qkv_bias: bool = False
    swa_window: int | None = None
    learned_pos: int = 0         # >0: learned positional table size (whisper)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    hybrid_attn_every: int = 0   # zamba2: shared attn block every N ssm layers
    # enc-dec (whisper: encoder frames are a stubbed modality frontend)
    encoder_layers: int = 0
    n_frames: int = 0
    # vlm (llama-3.2-vision: patch embeds stubbed)
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    # Paper technique: Bayesian LM head
    bayesian_head: bool = True
    uq_samples: int = 8
    head_mode: str = "rank16"    # paper | rank16 | moment
    sigma_init: float = 0.03
    prior_sigma: float = 0.1
    kl_weight: float = 1e-5
    # compute
    dtype: Any = jnp.bfloat16
    remat: str = "full"          # full | dots | none
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # mesh hints (set by the launcher; () disables constraints)
    batch_axes: tuple = ()
    model_axis_size: int = 0
    # §Perf I2b: explicit Megatron TP linears (shard_map row/col parallel
    # with bf16 psum) instead of GSPMD-inferred reductions, which the
    # CPU-backend partitioner materializes in f32 (2× wire).
    explicit_tp: bool = False

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def grng(self) -> GRNGConfig:
        return GRNGConfig()

    def head_bayes_cfg(self) -> BayesDenseConfig:
        return BayesDenseConfig(
            d_in=self.d_model, d_out=self.vocab_padded,
            sigma_init=self.sigma_init, prior_sigma=self.prior_sigma,
            grng=self.grng)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head µ+ρ)."""
        d, f, l = self.d_model, self.d_ff, self.n_layers
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn_p = d * hq + 2 * d * hkv + hq * d
        if self.n_experts:
            mlp_p = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            mlp_p = 3 * d * f if self.mlp == "swiglu" else 2 * d * f
        per_layer = attn_p + mlp_p + 2 * d
        total = l * per_layer + self.vocab_padded * d * 2
        if self.encoder_layers:
            total += self.encoder_layers * (attn_p + mlp_p + 2 * d)
            total += l * (attn_p + d)          # decoder xattn blocks
        if self.cross_attn_every:
            n_cross = l // self.cross_attn_every
            total += n_cross * (attn_p + mlp_p + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, f, l = self.d_model, self.d_ff, self.n_layers
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn_p = d * hq + 2 * d * hkv + hq * d
        mlp_p = self.top_k * 3 * d * f + d * self.n_experts
        return l * (attn_p + mlp_p + 2 * d) + self.vocab_padded * d * 2


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return "none"
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _maybe_remat(fn, cfg: ModelConfig):
    policy = _remat_policy(cfg)
    if policy == "none":
        return fn
    return jax.checkpoint(fn, policy=policy)


def _wsc(x, cfg: ModelConfig, *rest):
    """Constrain leading batch dim to the DP axes (launcher-provided)."""
    if not cfg.batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(tuple(cfg.batch_axes), *rest))


def _model_ax(cfg: ModelConfig, dim: int):
    """'model' when the launcher told us the axis size divides ``dim``."""
    if cfg.model_axis_size and dim % cfg.model_axis_size == 0:
        return "model"
    return None


def _tp_ok(cfg: ModelConfig, d_in: int, d_out: int) -> bool:
    if not (cfg.explicit_tp and cfg.batch_axes and cfg.model_axis_size > 1):
        return False
    from repro.launch.mesh import HAS_ABSTRACT_MESH, abstract_mesh_or
    if not HAS_ABSTRACT_MESH:
        return False  # explicit-TP is a current-jax-only perf path
    mesh = abstract_mesh_or()
    data = mesh.shape.get("data", 1)
    return d_out % cfg.model_axis_size == 0 and d_in % data == 0


def _tp_linear(x, w, cfg: ModelConfig, kind: str):
    """Explicit tensor-parallel matmul (Megatron row/col parallel).

    'col': w [D_in(fsdp:data), D_out(tp:model)] — no fwd collective, the
           bwd dgrad psum is emitted by shard_map's transpose in x.dtype.
    'row': w [D_in(tp:model), D_out(fsdp:data)] — ONE fwd psum in
           x.dtype (bf16), the whole point: the GSPMD partitioner on the
           CPU backend reduces these partials in f32.
    FSDP gathers of w over 'data' are explicit; their transpose is the
    reduce-scatter of the weight gradient.
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import abstract_mesh_or, shard_map_compat
    mesh = abstract_mesh_or()
    dp = tuple(cfg.batch_axes)
    lead = (dp,) + (None,) * (x.ndim - 2)

    if kind == "col":
        def body(x_loc, w_loc):
            w_full = lax.all_gather(w_loc, "data", axis=0, tiled=True)
            return x_loc @ w_full.astype(x_loc.dtype)
        return shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(*lead, None), P("data", "model")),
            out_specs=P(*lead, "model"))(x, w)

    def body(x_loc, w_loc):
        w_full = lax.all_gather(w_loc, "data", axis=1, tiled=True)
        y = x_loc @ w_full.astype(x_loc.dtype)
        return lax.psum(y, "model")
    return shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(*lead, "model"), P("model", "data")),
        out_specs=P(*lead, None))(x, w)


# ----------------------------------------------------------------------
# Parameter init
# ----------------------------------------------------------------------
def _init_attn_block(key, cfg: ModelConfig, l: int, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    dt = jnp.float32
    p = {
        "wq": jax.vmap(lambda k: blocks.dense_init(k, d, hq, dt))(
            jax.random.split(ks[0], l)),
        "wk": jax.vmap(lambda k: blocks.dense_init(k, d, hkv, dt))(
            jax.random.split(ks[1], l)),
        "wv": jax.vmap(lambda k: blocks.dense_init(k, d, hkv, dt))(
            jax.random.split(ks[2], l)),
        "wo": jax.vmap(lambda k: blocks.dense_init(k, hq, d, dt))(
            jax.random.split(ks[3], l)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((l, hq), dt)
        p["bk"] = jnp.zeros((l, hkv), dt)
        p["bv"] = jnp.zeros((l, hkv), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((l, cfg.head_dim), dt)
        p["k_norm"] = jnp.ones((l, cfg.head_dim), dt)
    return p


def _init_mlp_block(key, cfg: ModelConfig, l: int) -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.float32
    if cfg.mlp == "swiglu":
        return {
            "wi": jax.vmap(lambda k: blocks.dense_init(k, d, f, dt))(
                jax.random.split(ks[0], l)),
            "wg": jax.vmap(lambda k: blocks.dense_init(k, d, f, dt))(
                jax.random.split(ks[1], l)),
            "wo": jax.vmap(lambda k: blocks.dense_init(k, f, d, dt))(
                jax.random.split(ks[2], l)),
        }
    return {
        "wi": jax.vmap(lambda k: blocks.dense_init(k, d, f, dt))(
            jax.random.split(ks[0], l)),
        "bi": jnp.zeros((l, f), dt),
        "wo": jax.vmap(lambda k: blocks.dense_init(k, f, d, dt))(
            jax.random.split(ks[1], l)),
        "bo": jnp.zeros((l, d), dt),
    }


def _init_block_stack(key, cfg: ModelConfig, l: int, cross: bool = False,
                      with_xattn: bool = False) -> dict:
    ka, km, kx = jax.random.split(key, 3)
    p = {
        "attn": _init_attn_block(ka, cfg, l, cross),
        "ln1": jnp.ones((l, cfg.d_model), jnp.float32),
        "ln2": jnp.ones((l, cfg.d_model), jnp.float32),
    }
    if cfg.norm == "ln":
        p["ln1_b"] = jnp.zeros((l, cfg.d_model), jnp.float32)
        p["ln2_b"] = jnp.zeros((l, cfg.d_model), jnp.float32)
    if cfg.n_experts and not cross:
        p["moe"] = init_moe(km, l, cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        p["mlp"] = _init_mlp_block(km, cfg, l)
    if with_xattn:  # enc-dec decoder layer: self → cross → mlp
        p["xattn"] = _init_attn_block(kx, cfg, l, cross=True)
        p["lnx"] = jnp.ones((l, cfg.d_model), jnp.float32)
        if cfg.norm == "ln":
            p["lnx_b"] = jnp.zeros((l, cfg.d_model), jnp.float32)
    return p


def init_transformer(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": blocks.embed_init(keys[0], cfg.vocab_padded, cfg.d_model),
        "blocks": _init_block_stack(keys[1], cfg, cfg.n_layers,
                                    with_xattn=cfg.encoder_layers > 0),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.norm == "ln":
        params["final_norm_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.learned_pos:
        params["pos_embed"] = (
            jax.random.normal(keys[2], (cfg.learned_pos, cfg.d_model)) * 0.02)
    if cfg.encoder_layers:
        params["encoder"] = {
            "blocks": _init_block_stack(keys[3], cfg, cfg.encoder_layers),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "final_norm_b": jnp.zeros((cfg.d_model,), jnp.float32),
            "pos_embed": (jax.random.normal(keys[4], (cfg.n_frames, cfg.d_model))
                          * 0.02),
        }
    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        params["blocks_cross"] = _init_block_stack(keys[5], cfg, n_cross,
                                                   cross=True)
    if cfg.bayesian_head:
        params["head"] = bayes_layer.init(keys[6], cfg.head_bayes_cfg())
    else:
        params["head"] = {"w": blocks.dense_init(
            keys[6], cfg.d_model, cfg.vocab_padded)}
    return params


# ----------------------------------------------------------------------
# Block applications
# ----------------------------------------------------------------------
def _norm(h, scale, bias, cfg: ModelConfig):
    if cfg.norm == "ln":
        return blocks.layer_norm(h, scale, bias)
    return blocks.rms_norm(h, scale)


def _project_qkv(h, p, cfg: ModelConfig, memory=None):
    """Returns q [B,S,Hq,dh], k,v [B,Skv,Hkv,dh] (memory for cross-attn)."""
    src = h if memory is None else memory
    hq_dim = cfg.n_heads * cfg.head_dim
    if _tp_ok(cfg, h.shape[-1], hq_dim):
        q = _tp_linear(h, p["wq"], cfg, "col")
    else:
        q = h @ p["wq"].astype(h.dtype)
    k = src @ p["wk"].astype(h.dtype)
    v = src @ p["wv"].astype(h.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    b, s = q.shape[:2]
    skv = k.shape[1]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    q = _wsc(q, cfg, None, _model_ax(cfg, cfg.n_heads), None)
    k = _wsc(k, cfg, None, _model_ax(cfg, cfg.n_kv_heads), None)
    v = _wsc(v, cfg, None, _model_ax(cfg, cfg.n_kv_heads), None)
    if cfg.qk_norm and "q_norm" in p:
        q = blocks.rms_norm(q, p["q_norm"])
        k = blocks.rms_norm(k, p["k_norm"])
    return q, k, v


def _mlp_apply(h, lp, cfg: ModelConfig):
    if "moe" in lp:
        # Manual local dispatch pays one FSDP weight-gather per call —
        # amortized over 1M training tokens, ruinous for single-token
        # decode (S=1): there the GSPMD path with TP-sharded weights
        # moves only activations.
        if cfg.batch_axes and cfg.model_axis_size > 1 and h.shape[1] > 1:
            # Perf I1: manual local dispatch - routing is batch-parallel,
            # so no dispatch collectives; one TP psum + FSDP gathers only.
            from repro.launch.mesh import abstract_mesh_or
            from repro.models.moe import make_sharded_moe
            moe = make_sharded_moe(
                abstract_mesh_or(), top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                n_experts=cfg.n_experts, dp_axes=tuple(cfg.batch_axes))
            return moe(h, lp["moe"]["router"].astype(h.dtype),
                       lp["moe"]["wi"].astype(h.dtype),
                       lp["moe"]["wg"].astype(h.dtype),
                       lp["moe"]["wo"].astype(h.dtype))
        ep = ("model" if (cfg.model_axis_size
                          and cfg.n_experts % cfg.model_axis_size == 0)
              else None)
        y, aux = moe_apply(h, lp["moe"]["router"].astype(h.dtype),
                           lp["moe"]["wi"].astype(h.dtype),
                           lp["moe"]["wg"].astype(h.dtype),
                           lp["moe"]["wo"].astype(h.dtype),
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           ep_axis=ep)
        return y, aux
    p = lp["mlp"]
    if cfg.mlp == "swiglu":
        if _tp_ok(cfg, h.shape[-1], cfg.d_ff) and _tp_ok(
                cfg, cfg.d_ff, p["wo"].shape[1]):
            hi = jax.nn.silu(_tp_linear(h, p["wg"], cfg, "col")) * _tp_linear(
                h, p["wi"], cfg, "col")
            y = _tp_linear(hi, p["wo"], cfg, "row")
        else:
            y = blocks.swiglu(h, p["wi"].astype(h.dtype),
                              p["wg"].astype(h.dtype), p["wo"].astype(h.dtype))
    else:
        y = blocks.gelu_mlp(h, p["wi"].astype(h.dtype), p["bi"].astype(h.dtype),
                            p["wo"].astype(h.dtype), p["bo"].astype(h.dtype))
    return y, jnp.zeros((), jnp.float32)


def _xattn_full(h, lp, cfg: ModelConfig, memory):
    """Cross-attention sub-block (full sequence). Returns (delta, (xk, xv))."""
    hn = _norm(h, lp["lnx"], lp.get("lnx_b"), cfg)
    q, k, v = _project_qkv(hn, lp["xattn"], cfg, memory=memory)
    o = attn.chunked_attention(q, attn.expand_kv(k, cfg.n_heads),
                               attn.expand_kv(v, cfg.n_heads), causal=False,
                               chunk_q=cfg.attn_chunk_q,
                               chunk_kv=cfg.attn_chunk_kv)
    return o.reshape(*h.shape[:2], -1) @ lp["xattn"]["wo"].astype(h.dtype), (k, v)


def _block_full(h, lp, cfg: ModelConfig, positions, causal: bool, memory=None,
                kv_start=None):
    """One block: self-attn [→ cross-attn] → mlp. Returns (h, aux, caches)."""
    hn = _norm(h, lp["ln1"], lp.get("ln1_b"), cfg)
    q, k, v = _project_qkv(hn, lp["attn"], cfg)
    if cfg.use_rope:
        q = blocks.apply_rope(q, positions, cfg.rope_theta)
        k = blocks.apply_rope(k, positions, cfg.rope_theta)
    ke = _wsc(attn.expand_kv(k, cfg.n_heads), cfg, None,
              _model_ax(cfg, cfg.n_heads), None)
    ve = _wsc(attn.expand_kv(v, cfg.n_heads), cfg, None,
              _model_ax(cfg, cfg.n_heads), None)
    o = attn.chunked_attention(
        q, ke, ve, causal=causal, window=cfg.swa_window, kv_start=kv_start,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    o = _wsc(o, cfg, None, _model_ax(cfg, cfg.n_heads), None)
    of = o.reshape(*h.shape[:2], -1)
    if _tp_ok(cfg, lp["attn"]["wo"].shape[1], of.shape[-1]):
        h = h + _tp_linear(of, lp["attn"]["wo"], cfg, "row")
    else:
        h = h + of @ lp["attn"]["wo"].astype(h.dtype)
    h = _wsc(h, cfg, None, None)
    xkv = None
    if "xattn" in lp:
        delta, xkv = _xattn_full(h, lp, cfg, memory)
        h = h + delta
    hn = _norm(h, lp["ln2"], lp.get("ln2_b"), cfg)
    y, aux = _mlp_apply(hn, lp, cfg)
    return _wsc(h + y, cfg, None, None), aux, (k, v), xkv


def _block_decode(h, lp, cfg: ModelConfig, ck, cv, pos, rolling, xk=None,
                  xv=None, start=None):
    """Single-token block against KV cache (+ optional cross memory kv)."""
    hn = _norm(h, lp["ln1"], lp.get("ln1_b"), cfg)
    q, k, v = _project_qkv(hn, lp["attn"], cfg)
    if cfg.use_rope:
        positions = jnp.full((h.shape[0], 1), pos, jnp.int32)
        q = blocks.apply_rope(q, positions, cfg.rope_theta)
        k = blocks.apply_rope(k, positions, cfg.rope_theta)
    ck, cv = attn.cache_update(ck, cv, k, v, pos, rolling=rolling)
    o = attn.decode_attention(q, ck, cv, pos,
                              window=cfg.swa_window, rolling=rolling,
                              start=start)
    h = h + o.reshape(*h.shape[:2], -1) @ lp["attn"]["wo"].astype(h.dtype)
    if "xattn" in lp:
        hn = _norm(h, lp["lnx"], lp.get("lnx_b"), cfg)
        qx = (hn @ lp["xattn"]["wq"].astype(h.dtype)).reshape(
            h.shape[0], 1, cfg.n_heads, cfg.head_dim)
        ox = attn.decode_attention(qx, xk, xv, jnp.int32(xk.shape[1] - 1))
        h = h + ox.reshape(*h.shape[:2], -1) @ lp["xattn"]["wo"].astype(h.dtype)
    hn = _norm(h, lp["ln2"], lp.get("ln2_b"), cfg)
    y, _ = _mlp_apply(hn, lp, cfg)
    return h + y, ck, cv


def _cross_layer_full(h, lp, cfg: ModelConfig, memory):
    """Dedicated VLM cross-attention layer (own MLP, llama-3.2 style)."""
    hn = _norm(h, lp["ln1"], lp.get("ln1_b"), cfg)
    q, k, v = _project_qkv(hn, lp["attn"], cfg, memory=memory)
    o = attn.chunked_attention(q, attn.expand_kv(k, cfg.n_heads),
                               attn.expand_kv(v, cfg.n_heads), causal=False,
                               chunk_q=cfg.attn_chunk_q,
                               chunk_kv=cfg.attn_chunk_kv)
    h = h + o.reshape(*h.shape[:2], -1) @ lp["attn"]["wo"].astype(h.dtype)
    hn = _norm(h, lp["ln2"], lp.get("ln2_b"), cfg)
    y, aux = _mlp_apply(hn, lp, cfg)
    return h + y, aux, (k, v)


def _cross_layer_decode(h, lp, cfg: ModelConfig, xk, xv):
    hn = _norm(h, lp["ln1"], lp.get("ln1_b"), cfg)
    q = (hn @ lp["attn"]["wq"].astype(h.dtype)).reshape(
        h.shape[0], 1, cfg.n_heads, cfg.head_dim)
    o = attn.decode_attention(q, xk, xv, jnp.int32(xk.shape[1] - 1))
    h = h + o.reshape(*h.shape[:2], -1) @ lp["attn"]["wo"].astype(h.dtype)
    hn = _norm(h, lp["ln2"], lp.get("ln2_b"), cfg)
    y, _ = _mlp_apply(hn, lp, cfg)
    return h + y


# ----------------------------------------------------------------------
# Trunk forward
# ----------------------------------------------------------------------
def _encode(params, frames, cfg: ModelConfig):
    enc = params["encoder"]
    eh = frames.astype(cfg.dtype) + enc["pos_embed"].astype(cfg.dtype)[None]
    epos = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32)[None],
                            frames.shape[:2])

    def body(h, lp):
        h, aux, _, _ = _block_full(h, lp, cfg, epos, causal=False)
        return h, aux

    body = _maybe_remat(body, cfg)
    eh, _ = lax.scan(body, eh, enc["blocks"])
    return blocks.layer_norm(eh, enc["final_norm"], enc["final_norm_b"])


def trunk_forward(params, tokens, cfg: ModelConfig, *, frames=None,
                  image_embeds=None, collect_cache: bool = False,
                  kv_start=None):
    """Token trunk -> (hidden [B,S,D], aux, caches dict|None, memory).

    kv_start: optional [B] first-valid positions for left-padded rows
    (continuous-batching admission) — masks self-attention only.
    """
    b, s = tokens.shape
    h = _wsc(params["embed"].astype(cfg.dtype)[tokens], cfg, None, None)
    if cfg.learned_pos:
        h = h + params["pos_embed"][:s].astype(cfg.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    memory = None
    if cfg.encoder_layers:
        assert frames is not None, "whisper needs stub frame embeddings"
        memory = _encode(params, frames, cfg)
    if cfg.cross_attn_every:
        assert image_embeds is not None, "vlm needs stub patch embeddings"
        memory = image_embeds.astype(cfg.dtype)

    def self_body(h, lp):
        h, aux, kv, xkv = _block_full(h, lp, cfg, positions, causal=True,
                                      memory=memory, kv_start=kv_start)
        outs = (aux, kv if collect_cache else None,
                xkv if (collect_cache and xkv is not None) else None)
        return h, outs

    self_body_r = _maybe_remat(self_body, cfg)
    caches: dict | None = {} if collect_cache else None

    if cfg.cross_attn_every and "blocks_cross" in params:
        every = cfg.cross_attn_every
        n_groups = params["blocks_cross"]["ln1"].shape[0]
        grouped = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]),
            params["blocks"])

        def cross_body(h, lp):
            h, aux, xkv = _cross_layer_full(h, lp, cfg, memory)
            return h, (aux, xkv if collect_cache else None)

        cross_body_r = _maybe_remat(cross_body, cfg)

        def group_fn(h, xs):
            gself, glp = xs
            h, (aux, kvs, _) = lax.scan(self_body_r, h, gself)
            h, (aux_c, xkv) = cross_body_r(h, glp)
            return h, (aux.sum() + aux_c, kvs, xkv)

        h, (aux, kvs, xkvs) = lax.scan(group_fn, h,
                                       (grouped, params["blocks_cross"]))
        aux = aux.sum()
        if collect_cache:
            k, v = kvs  # [G, E, B, S, Hkv, dh]
            caches["k"] = k.reshape(-1, *k.shape[2:])
            caches["v"] = v.reshape(-1, *v.shape[2:])
            caches["xk"], caches["xv"] = xkvs  # [G, B, Sm, Hkv, dh]
    else:
        h, (aux, kvs, xkvs) = lax.scan(self_body_r, h, params["blocks"])
        aux = aux.sum()
        if collect_cache:
            caches["k"], caches["v"] = kvs
            if cfg.encoder_layers:
                caches["xk"], caches["xv"] = xkvs

    if cfg.norm == "ln":
        h = blocks.layer_norm(h, params["final_norm"], params["final_norm_b"])
    else:
        h = blocks.rms_norm(h, params["final_norm"])
    return h, aux, caches, memory


# ----------------------------------------------------------------------
# Heads + losses
# ----------------------------------------------------------------------
def head_logits_train(params_head, h, cfg: ModelConfig, step):
    """Single reparameterized-sample logits + KL (Bayes-by-backprop)."""
    if not cfg.bayesian_head:
        return h @ params_head["w"].astype(h.dtype), jnp.zeros((), jnp.float32)
    bcfg = cfg.head_bayes_cfg()
    w = bayes_layer.sample_weights_at(params_head, bcfg, step)
    kl = bayes_layer.kl_divergence(params_head, bcfg)
    return h @ w.astype(h.dtype), kl


def train_loss(params, batch, cfg: ModelConfig, step=0):
    """Next-token CE + KL + MoE aux. batch: dict(tokens, labels, ...)."""
    h, aux, _, _ = trunk_forward(
        params, batch["tokens"], cfg,
        frames=batch.get("frames"), image_embeds=batch.get("image_embeds"))
    logits, kl = head_logits_train(params["head"], h, cfg, step)
    logits = _wsc(logits, cfg, None, _model_ax(cfg, cfg.vocab_padded))
    ce = blocks.causal_cross_entropy(logits, batch["labels"], cfg.vocab)
    n_tokens = batch["tokens"].shape[0] * batch["tokens"].shape[1]
    loss = ce + cfg.kl_weight * kl / n_tokens + cfg.aux_loss_weight * aux
    return loss, {"ce": ce, "kl": kl, "aux": aux}


# ----------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------
def prefill(params, tokens, cfg: ModelConfig, *, cache_len: int,
            frames=None, image_embeds=None, prompt_lengths=None):
    """Run the prompt, build KV caches sized ``cache_len``.

    Returns (cache dict, last-position hidden [B, D]).  SWA models whose
    cache_len exceeds the window get a rolling cache of size window.

    ``prompt_lengths`` [B]: true prompt lengths of LEFT-padded rows —
    the continuous-batching admission path (serving/engine.py).  Pad
    positions are masked out of attention here and recorded as a per-
    slot ``start`` in the cache so decode keeps masking them.  Exact for
    RoPE trunks: a slot's tokens shift uniformly, and RoPE scores depend
    only on relative distance.
    """
    b, s = tokens.shape
    rolling = cfg.swa_window is not None and cache_len > cfg.swa_window
    sc = min(cache_len, cfg.swa_window) if rolling else cache_len
    kv_start = None
    if prompt_lengths is not None:
        if rolling:
            raise ValueError(
                "prompt_lengths (left-padded admission) is not supported "
                f"with a rolling SWA cache (cache_len={cache_len} > "
                f"window={cfg.swa_window}): decode_attention cannot apply "
                "the per-slot start mask to a circular buffer")
        kv_start = (s - prompt_lengths).astype(jnp.int32)    # [B]
    h, _, caches, _ = trunk_forward(
        params, tokens, cfg, frames=frames, image_embeds=image_embeds,
        collect_cache=True, kv_start=kv_start)

    def fit(x):  # [L, B, S, Hkv, dh] -> [L, B, sc, Hkv, dh]
        if s >= sc:
            return x[:, :, s - sc:]
        return jnp.pad(x, ((0, 0), (0, 0), (0, sc - s), (0, 0), (0, 0)))

    cache = {"k": fit(caches["k"]), "v": fit(caches["v"]),
             "pos": jnp.int32(s)}
    if prompt_lengths is not None:
        # Front-truncated prompt (s > sc, linear cache): the valid
        # region shifts with the truncation.
        cache["start"] = kv_start if s <= sc else jnp.maximum(
            kv_start - (s - sc), 0)
    if "xk" in caches:
        cache["xk"], cache["xv"] = caches["xk"], caches["xv"]
    return cache, h[:, -1]


def _head_serving(params, cfg: ModelConfig):
    """Serving head params: prepared {mu_prime, sigma} or raw fallback."""
    hp = params["head"]
    if "mu_prime" in hp:
        return {"mu_prime": hp["mu_prime"].astype(cfg.dtype),
                "sigma": hp["sigma"].astype(cfg.dtype)}
    from repro.core.bayes_layer import sigma_of
    return {"mu_prime": hp["mu"].astype(cfg.dtype),
            "sigma": sigma_of(hp).astype(cfg.dtype)}


def decode_hidden(params, cache, token, cfg: ModelConfig):
    """One trunk decode step WITHOUT the Bayesian head.

    token: [B,1] -> (last hidden [B, D], new cache).  The serving engine
    uses this split so it can sample the head *adaptively* — a small
    first draw, then escalations — instead of a fixed R fused into the
    step (serving/adaptive.py).  ``decode_step`` composes this with
    ``apply_bayes_head`` and is unchanged in behavior.

    Honors ``cache['start']`` ([B] first-valid positions) written by
    prefill for left-padded continuous-batching admissions.
    """
    pos = cache["pos"]
    start = cache.get("start")
    h = params["embed"].astype(cfg.dtype)[token]             # [B, 1, D]
    if cfg.learned_pos:
        pe = lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, axis=0)
        h = h + pe.astype(cfg.dtype)[None, 0:1, 0]

    rolling = (cfg.swa_window is not None
               and cache["k"].shape[2] <= cfg.swa_window)

    if cfg.cross_attn_every and "blocks_cross" in params:
        every = cfg.cross_attn_every
        n_groups = params["blocks_cross"]["ln1"].shape[0]
        grouped = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]),
            params["blocks"])
        kg = cache["k"].reshape(n_groups, every, *cache["k"].shape[1:])
        vg = cache["v"].reshape(n_groups, every, *cache["v"].shape[1:])

        def self_body(h, xs):
            lp, ck, cv = xs
            h, ck, cv = _block_decode(h, lp, cfg, ck, cv, pos, rolling,
                                      start=start)
            return h, (ck, cv)

        def group_body(h, xs):
            gself, ck, cv, glp, xk, xv = xs
            h, (ck, cv) = lax.scan(self_body, h, (gself, ck, cv))
            h = _cross_layer_decode(h, glp, cfg, xk, xv)
            return h, (ck, cv)

        h, (ck, cv) = lax.scan(
            group_body, h, (grouped, kg, vg, params["blocks_cross"],
                            cache["xk"], cache["xv"]))
        new_cache = dict(cache, k=ck.reshape(-1, *ck.shape[2:]),
                         v=cv.reshape(-1, *cv.shape[2:]), pos=pos + 1)
    elif cfg.encoder_layers:
        def body(h, xs):
            lp, ck, cv, xk, xv = xs
            h, ck, cv = _block_decode(h, lp, cfg, ck, cv, pos, rolling,
                                      xk=xk, xv=xv, start=start)
            return h, (ck, cv)

        h, (ck, cv) = lax.scan(body, h, (params["blocks"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
        new_cache = dict(cache, k=ck, v=cv, pos=pos + 1)
    else:
        def body(h, xs):
            lp, ck, cv = xs
            h, ck, cv = _block_decode(h, lp, cfg, ck, cv, pos, rolling,
                                      start=start)
            return h, (ck, cv)

        h, (ck, cv) = lax.scan(body, h, (params["blocks"], cache["k"],
                                         cache["v"]))
        new_cache = dict(cache, k=ck, v=cv, pos=pos + 1)

    if cfg.norm == "ln":
        h = blocks.layer_norm(h, params["final_norm"], params["final_norm_b"])
    else:
        h = blocks.rms_norm(h, params["final_norm"])
    return h[:, 0], new_cache                                # [B, D]


def decode_step(params, cache, token, cfg: ModelConfig):
    """One decode step. token: [B,1] -> (logit_samples [R,B,Vp], cache).

    The selection stream is indexed by decode position (write-free
    random access — see lfsr.indexed_selections) so every generated
    token sees fresh CLT-GRNG samples, as the hardware's free-running
    LFSR would provide.
    """
    pos = cache["pos"]
    x, new_cache = decode_hidden(params, cache, token, cfg)
    return apply_bayes_head(params, x, cfg, pos), new_cache


def apply_bayes_head(params, x, cfg: ModelConfig, pos):
    """R logit samples from the Bayesian head at decode position ``pos``."""
    from repro.core.sampling import BayesHeadConfig, logit_samples
    if not cfg.bayesian_head:
        return (x @ params["head"]["w"].astype(x.dtype))[None]
    hcfg = BayesHeadConfig(num_samples=cfg.uq_samples, mode=cfg.head_mode,
                           grng=cfg.grng, compute_dtype=cfg.dtype)
    head = _head_serving(params, cfg)
    idx = (jnp.asarray(pos, jnp.uint32) * jnp.uint32(cfg.uq_samples)
           + jnp.arange(cfg.uq_samples, dtype=jnp.uint32))
    sel = indexed_selections(cfg.grng.lfsr_seed, idx)
    return logit_samples(head, x, hcfg, sel=sel)
