"""Pallas TPU kernel: fused sample→statistics decision update.

The serving hot path used to be a two-step dataflow:

    mix_samples:   [B,N,16] basis × [R,B,16] selections → [R,B,N] in HBM
    update_stats:  softmax + entropy over [R,B,N]        → O(B·N) sums

i.e. every triage decision materialized the full logit-sample tensor
only to immediately collapse it into five running sums.  The paper's
whole pitch is that a Bayesian sample costs 640 aJ on the FeFET engine;
paying an HBM round-trip of R·B·N floats per decision on the software
twin betrays that economy (Bayes2IMC and FeBiM flag exactly this
per-sample data movement as the barrier to in-memory BNN deployment).

This kernel fuses the whole decision update.  It consumes the rank-16
activation basis (``y_mu``, ``x_sigma``, ``m``, and ``x_sigsq`` on
degraded chip instances) plus the per-slot selection table and the
active-slot mask, and emits ONLY the sufficient-statistic deltas

    {sum_p [B,N], sum_psq [B,N], sum_ent [B], sum_entsq [B]}

(``n`` is the trivial count; the wrapper adds it).  Mixing, read-noise
projection, softmax, entropy, and the masked stats update all happen in
VMEM on [R, bB, bN] blocks; the peak HBM footprint of a decision no
longer carries an R·B·N term.

The softmax is a flash-attention-style ONLINE logsumexp over N: the
grid runs two phases per batch block — phase 0 streams the N blocks
once accumulating the running (max, sumexp) per (sample, row); phase 1
streams them again, normalizes each block against the finished
logsumexp, and accumulates the statistics.  Vocab-scale heads therefore
never hold [R, B, V] anywhere, in HBM *or* VMEM.

Read-noise twin: on a degraded instance (``cfg.read_sigma > 0``) each
logit sample carries the projected cycle-to-cycle read noise
N(0, read_sigma²·x_sigsq), hashed from the ABSOLUTE selection-stream
index with the same ``hash3`` stream as ``core.sampling.mix_samples``
and the rank16 ``bayes_mvm`` kernel — fused-path serving matches the
jnp fast path draw-for-draw, and escalation at later offsets extends
the stream exactly.

Oracle: ``kernels/ref.decision_stats_ref`` (pure jnp, no blocking),
asserted against ``update_stats(mix_samples(...))`` and against this
kernel in tests/test_decision_kernel.py.

VMEM per grid step (bb=8, bn=128, R=20, f32):
  m block 8·128·16·4 = 64K, mixed [R, bb, bn] 20·8·128·4 = 80K,
  row scratch 3·(R·bb)·4 ≈ 2K, out blocks 2·4K  →  well under 1 MB.
At vocab scale (bn=128 of N=151k) the footprint is unchanged — the
N dimension is streamed, never resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.clt_grng import GRNGConfig
from repro.kernels.backend import resolve_interpret
from repro.kernels.clt_grng_kernel import _gauss_of, _hash3

_NEG = -1.0e30            # masked-logit fill: exp underflows to exactly 0


def _mix_logits(m_blk, sel, y_mu, x_sigma, x_sigsq, sidx, rows, *,
                cfg: GRNGConfig, i, k, bb, bn, n: int):
    """[R, bb, bn] logit samples for one (batch, column) block — the
    in-VMEM replica of core.sampling.mix_samples, padded cols → -1e30."""
    # per-slot mixing: [bb,R,16] × [bb,bn,16] → [bb,R,bn] (batched MXU)
    mix = jax.lax.dot_general(
        jnp.transpose(sel, (1, 0, 2)), m_blk,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    mix = jnp.transpose(mix, (1, 0, 2))                  # [R, bb, bn]
    num = mix - cfg.sum_mean * x_sigma[None]
    if cfg.read_sigma:
        cols = (jnp.uint32(k * bn)
                + jax.lax.broadcasted_iota(jnp.uint32, (bb, bn), 1))
        # same stream as mix_samples: hash3(sample_idx, slot, column).
        # ``rows`` is the [bb, 1] block of GLOBAL slot ids — under the
        # shard_map lowering each shard hashes with its global rows, so
        # sharded draws match the single-device stream bit-for-bit.
        h = _hash3(sidx[:, :, None], rows[None], cols[None],
                   cfg.noise_seed)                       # [R, bb, bn]
        sigma_read = cfg.read_sigma * jnp.sqrt(
            jnp.maximum(x_sigsq, 0.0))                   # [bb, bn]
        num = num + _gauss_of(h) * sigma_read[None]
    logits = y_mu[None] + num * (1.0 / cfg.sum_std)
    valid = (k * bn + jax.lax.broadcasted_iota(
        jnp.int32, (bb, bn), 1)) < n
    return jnp.where(valid[None], logits, _NEG)


def _decision_kernel(*refs, cfg: GRNGConfig, bb: int, bn: int, n: int):
    """Grid (nb, 2, nn): phase 0 = online (max, sumexp) over the N
    stream; phase 1 = normalize + accumulate masked statistic deltas."""
    if cfg.read_sigma:
        (y_mu_ref, xs_ref, m_ref, sel_ref, mask_ref, xq_ref, sidx_ref,
         rows_ref,
         out_p_ref, out_psq_ref, out_ent_ref, out_entsq_ref,
         mrun_ref, lrun_ref, ent_ref) = refs
    else:
        (y_mu_ref, xs_ref, m_ref, sel_ref, mask_ref,
         out_p_ref, out_psq_ref, out_ent_ref, out_entsq_ref,
         mrun_ref, lrun_ref, ent_ref) = refs
        xq_ref = sidx_ref = rows_ref = None
    i = pl.program_id(0)
    phase = pl.program_id(1)
    k = pl.program_id(2)

    logits = _mix_logits(
        m_ref[...], sel_ref[...].astype(jnp.float32),
        y_mu_ref[...].astype(jnp.float32),
        xs_ref[...].astype(jnp.float32),
        xq_ref[...].astype(jnp.float32) if cfg.read_sigma else None,
        sidx_ref[...] if cfg.read_sigma else None,
        rows_ref[...] if cfg.read_sigma else None,
        cfg=cfg, i=i, k=k, bb=bb, bn=bn, n=n)            # [R, bb, bn]

    @pl.when((phase == 0) & (k == 0))
    def _init():
        mrun_ref[...] = jnp.full_like(mrun_ref, _NEG)
        lrun_ref[...] = jnp.zeros_like(lrun_ref)

    @pl.when(phase == 0)
    def _pass1():                            # online logsumexp update
        m_old = mrun_ref[...]                            # [R, bb]
        m_new = jnp.maximum(m_old, logits.max(-1))
        scale = jnp.exp(m_old - m_new)
        lrun_ref[...] = (lrun_ref[...] * scale
                         + jnp.exp(logits - m_new[..., None]).sum(-1))
        mrun_ref[...] = m_new

    @pl.when(phase == 1)
    def _pass2():                            # normalize + accumulate
        mask = mask_ref[...]                             # [bb, 1] f32
        lse = mrun_ref[...] + jnp.log(lrun_ref[...])     # [R, bb]
        logp = logits - lse[..., None]
        p = jnp.exp(logp)                    # padded cols: exactly 0
        out_p_ref[...] = p.sum(0) * mask
        out_psq_ref[...] = (p * p).sum(0) * mask

        @pl.when(k == 0)
        def _():
            ent_ref[...] = jnp.zeros_like(ent_ref)
        ent_ref[...] += -(p * logp).sum(-1)              # [R, bb]

        @pl.when(k == pl.num_programs(2) - 1)
        def _():
            ent = ent_ref[...]
            out_ent_ref[...] = ent.sum(0)[:, None] * mask
            out_entsq_ref[...] = (ent * ent).sum(0)[:, None] * mask


def _round_up(v: int, m: int) -> int:
    return v + (-v) % m


@functools.partial(jax.jit, static_argnames=(
    "cfg", "bb", "bn", "interpret"))
def decision_stats_pallas(y_mu, x_sigma, m, sel, cfg: GRNGConfig,
                          x_sigsq=None, sample_idx=None, mask=None,
                          rows=None, bb: int = 0, bn: int = 128,
                          interpret: bool | None = None) -> dict:
    """Fused decision-statistic deltas for one escalation round.

    y_mu/x_sigma: [B, N]; m: [B, N, 16] (``activation_basis``);
    sel: [R, B, 16] or [R, 16] selection vectors; x_sigsq: [B, N]
    (required when ``cfg.read_sigma > 0``); sample_idx: [R, B] or [R]
    absolute stream indices (the read-noise key — required on degraded
    instances, matching ``adaptive.stream_indices``); rows: [B] uint32
    GLOBAL slot ids for the read-noise hash (None = ``arange(B)``; a
    shard passes its global offsets so sharded draws match the
    single-device stream); mask: [B] bool — slots whose stats should
    advance (None = all).

    Returns the per-round deltas, already masked (inactive rows are 0):
    ``{sum_p [B,N] f32, sum_psq [B,N], sum_ent [B], sum_entsq [B]}`` —
    add them to running stats (``kernels.ops.decision_update`` does,
    together with the ``n`` count).  ``interpret=None`` auto-detects
    the backend (kernels/backend.py).
    """
    interpret = resolve_interpret(interpret)
    b, n = y_mu.shape
    if sel.ndim == 2:
        sel = jnp.broadcast_to(sel[:, None, :], (sel.shape[0], b, 16))
    r = sel.shape[0]
    if bb <= 0:
        bb = min(128, _round_up(b, 8))
    bp, np_ = _round_up(b, bb), _round_up(n, bn)
    grid = (bp // bb, 2, np_ // bn)

    def pad2(a):
        return jnp.pad(a.astype(jnp.float32),
                       ((0, bp - b), (0, np_ - n)))

    mask_col = (jnp.ones((b, 1), jnp.float32) if mask is None
                else jnp.asarray(mask).astype(jnp.float32).reshape(b, 1))
    operands = [
        pad2(y_mu), pad2(x_sigma),
        jnp.pad(m.astype(jnp.float32),
                ((0, bp - b), (0, np_ - n), (0, 0))),
        jnp.pad(sel.astype(jnp.float32), ((0, 0), (0, bp - b), (0, 0))),
        jnp.pad(mask_col, ((0, bp - b), (0, 0))),
    ]
    in_specs = [
        pl.BlockSpec((bb, bn), lambda i, p, k: (i, k)),          # y_mu
        pl.BlockSpec((bb, bn), lambda i, p, k: (i, k)),          # x_sigma
        pl.BlockSpec((bb, bn, 16), lambda i, p, k: (i, k, 0)),   # m
        pl.BlockSpec((r, bb, 16), lambda i, p, k: (0, i, 0)),    # sel
        pl.BlockSpec((bb, 1), lambda i, p, k: (i, 0)),           # mask
    ]
    if cfg.read_sigma:
        assert x_sigsq is not None, "degraded instance needs x_sigsq"
        assert sample_idx is not None, \
            "degraded instance needs absolute stream indices"
        sample_idx = jnp.asarray(sample_idx, jnp.uint32)
        if sample_idx.ndim == 1:
            sample_idx = jnp.broadcast_to(sample_idx[:, None], (r, b))
        if rows is None:
            rows = jnp.arange(b, dtype=jnp.uint32)
        rows = jnp.asarray(rows, jnp.uint32).reshape(b, 1)
        operands += [pad2(x_sigsq),
                     jnp.pad(sample_idx, ((0, 0), (0, bp - b))),
                     jnp.pad(rows, ((0, bp - b), (0, 0)))]
        in_specs += [
            pl.BlockSpec((bb, bn), lambda i, p, k: (i, k)),      # x_sigsq
            pl.BlockSpec((r, bb), lambda i, p, k: (0, i)),       # sample_idx
            pl.BlockSpec((bb, 1), lambda i, p, k: (i, 0)),       # rows
        ]

    out = pl.pallas_call(
        functools.partial(_decision_kernel, cfg=cfg, bb=bb, bn=bn, n=n),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, bn), lambda i, p, k: (i, k)),      # sum_p
            pl.BlockSpec((bb, bn), lambda i, p, k: (i, k)),      # sum_psq
            pl.BlockSpec((bb, 1), lambda i, p, k: (i, 0)),       # sum_ent
            pl.BlockSpec((bb, 1), lambda i, p, k: (i, 0)),       # sum_entsq
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, np_), jnp.float32),
            jax.ShapeDtypeStruct((bp, np_), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        ],
        scratch_shapes=(
            [pltpu.VMEM((r, bb), jnp.float32),       # running max
             pltpu.VMEM((r, bb), jnp.float32),       # running sumexp
             pltpu.VMEM((r, bb), jnp.float32)]),     # entropy accumulator
        interpret=interpret,
    )(*operands)
    sum_p, sum_psq, sum_ent, sum_entsq = out
    return {"sum_p": sum_p[:b, :n], "sum_psq": sum_psq[:b, :n],
            "sum_ent": sum_ent[:b, 0], "sum_entsq": sum_entsq[:b, 0]}


def decision_stats_sharded(y_mu, x_sigma, m, sel, cfg: GRNGConfig, *,
                           mesh, axis: str, x_sigsq=None, sample_idx=None,
                           mask=None, rows=None, bb: int = 0, bn: int = 128,
                           interpret: bool | None = None) -> dict:
    """Shard_map-native fused decision update over the slot (batch) axis.

    Each shard runs its own ``decision_stats_pallas`` grid on its local
    slots — every statistic in the output dict is slot-local, so the
    round's data path needs NO cross-device collectives.  Bit-identity
    with the single-device kernel comes from two global keys that shard
    trivially along B: ``sample_idx`` (absolute selection-stream index,
    already per-slot) and ``rows`` (global slot ids for the hash3
    read-noise stream; default ``arange(B)`` so shard k hashes with its
    true global offsets instead of local 0..B/k-1).

    ``interpret`` is resolved ONCE here (per-call arg > scoped override
    > env > backend auto-detect — see kernels/backend.py) and passed as
    a concrete bool into every shard, so all shards lower identically.

    Requires ``B % mesh.shape[axis] == 0``; callers fall back to the
    unsharded kernel otherwise.
    """
    from repro.launch.mesh import shard_map_compat

    interpret = resolve_interpret(interpret)
    b, _ = y_mu.shape
    shards = mesh.shape[axis]
    if b % shards:
        raise ValueError(
            f"batch {b} not divisible by mesh axis {axis!r}={shards}")
    if sel.ndim == 2:
        sel = jnp.broadcast_to(sel[:, None, :], (sel.shape[0], b, 16))
    r = sel.shape[0]
    if mask is None:
        mask = jnp.ones((b,), jnp.bool_)
    P = jax.sharding.PartitionSpec

    if cfg.read_sigma:
        assert x_sigsq is not None, "degraded instance needs x_sigsq"
        assert sample_idx is not None, \
            "degraded instance needs absolute stream indices"
        sample_idx = jnp.asarray(sample_idx, jnp.uint32)
        if sample_idx.ndim == 1:
            sample_idx = jnp.broadcast_to(sample_idx[:, None], (r, b))
        if rows is None:
            rows = jnp.arange(b, dtype=jnp.uint32)
        rows = jnp.asarray(rows, jnp.uint32)

        def local(y_mu, x_sigma, m, sel, mask, x_sigsq, sample_idx, rows):
            return decision_stats_pallas(
                y_mu, x_sigma, m, sel, cfg, x_sigsq=x_sigsq,
                sample_idx=sample_idx, mask=mask, rows=rows,
                bb=bb, bn=bn, interpret=interpret)

        args = (y_mu, x_sigma, m, sel, mask, x_sigsq, sample_idx, rows)
        in_specs = (P(axis), P(axis), P(axis), P(None, axis), P(axis),
                    P(axis), P(None, axis), P(axis))
    else:

        def local(y_mu, x_sigma, m, sel, mask):
            return decision_stats_pallas(
                y_mu, x_sigma, m, sel, cfg, mask=mask,
                bb=bb, bn=bn, interpret=interpret)

        args = (y_mu, x_sigma, m, sel, mask)
        in_specs = (P(axis), P(axis), P(axis), P(None, axis), P(axis))

    fn = shard_map_compat(local, mesh=mesh, in_specs=in_specs,
                          out_specs=P(axis))
    return fn(*args)
