"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes *bit-equivalent semantics* to its kernel
counterpart (same hash, same selection network, same ADC order of
operations) with no blocking — the ground truth for the per-kernel
allclose sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import clt_grng as g
from repro.core import quant as q
from repro.core.hashing import gaussianish, hash3, uniform_bit
from repro.core.lfsr import swapper_select, lfsr_states


def grng_eps_ref(cfg: g.GRNGConfig, n_rows: int, n_cols: int,
                 num_samples: int, sample0: int = 0,
                 row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """ε block oracle -> [R, n_rows, n_cols] float32 (layer granularity)."""
    return g.eps(cfg, n_rows, n_cols, num_samples, sample0, row0, col0)


def _currents_j(cfg: g.GRNGConfig, rows, cols, j) -> jnp.ndarray:
    h = hash3(rows, cols, jnp.uint32(j), cfg.seed)
    out = (cfg.i_lo + cfg.delta_i * uniform_bit(h)
           + cfg.gamma * gaussianish(h))
    if cfg.imprint:
        hi = hash3(rows, cols, jnp.uint32(j), cfg.imprint_seed)
        out = out + cfg.imprint * gaussianish(hi)
    return out


def bayes_mvm_ref(x: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                  cfg: g.GRNGConfig, num_samples: int, sample0: int = 0,
                  row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """Fused Bayesian MVM oracle (no ADC): [R, B, N] float32.

    out[r] = x @ (mu + sigma * eps_r) with layer-shared selection.
    """
    kdim, n = mu.shape
    x = x.astype(jnp.float32)
    mu = mu.astype(jnp.float32)
    sigma = sigma.astype(jnp.float32)
    eps = grng_eps_ref(cfg, kdim, n, num_samples, sample0, row0, col0)
    w = mu[None] + sigma[None] * eps               # [R, K, N]
    return jnp.einsum("bk,rkn->rbn", x, w)


def bayes_mvm_rank16_ref(x: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                         cfg: g.GRNGConfig, num_samples: int, sample0: int = 0,
                         row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """Rank-16 kernel oracle: [R, B, N] float32.

    With ``cfg.read_sigma == 0`` this is identical to ``bayes_mvm_ref``.
    On a degraded instance the rank-16 path carries the cycle-to-cycle
    read noise as its exact logit-level projection instead of per-cell
    draws: sample r of logit (b, n) gains
        read_sigma · √(Σ_k x_bk² σ_kn²) · gaussianish(hash3(s₀+r, b, n))
    pre-standardization — the same hash stream ``mix_samples`` uses (and
    the fused rank16 kernel reproduces), keyed by the ABSOLUTE sample
    index so escalation at later ``sample0`` extends the stream exactly.
    """
    b = x.shape[0]
    _, n = mu.shape
    cfg0 = dataclasses.replace(cfg, read_sigma=0.0)
    y = bayes_mvm_ref(x, mu, sigma, cfg0, num_samples, sample0, row0, col0)
    if cfg.read_sigma:
        x32 = x.astype(jnp.float32)
        s32 = sigma.astype(jnp.float32)
        x_sigsq = (x32 * x32) @ (s32 * s32)                  # [B, N]
        key = sample0 + jnp.arange(num_samples, dtype=jnp.uint32)
        h = hash3(key[:, None, None],
                  jnp.arange(b, dtype=jnp.uint32)[None, :, None],
                  col0 + jnp.arange(n, dtype=jnp.uint32)[None, None, :],
                  cfg.noise_seed)                            # [R, B, N]
        sigma_read = cfg.read_sigma * jnp.sqrt(jnp.maximum(x_sigsq, 0.0))
        y = y + gaussianish(h) * sigma_read[None] / cfg.sum_std
    return y


def bayes_mvm_adc_ref(x: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                      cfg: g.GRNGConfig, qcfg: q.QuantConfig,
                      num_samples: int, sample0: int = 0,
                      row0: int = 0, col0: int = 0) -> jnp.ndarray:
    """CIM numeric-path oracle: per-sample σε MVM with 64-deep 6-bit ADC.

    Hardware order of operations (paper §IV-A): for each sample r the
    µ partial sums and the σε partial sums are *separately* digitized
    per 64-row chunk, then accumulated digitally.
    """
    b, kdim = x.shape
    _, n = mu.shape
    chunk = qcfg.chunk
    assert kdim % chunk == 0, "oracle expects chunk-aligned K"
    kc = kdim // chunk
    x32 = x.astype(jnp.float32)
    eps = grng_eps_ref(cfg, kdim, n, num_samples, sample0, row0, col0)

    xb = x32.reshape(b, kc, chunk)
    mub = mu.astype(jnp.float32).reshape(kc, chunk, n)
    x_rms = jnp.sqrt(jnp.mean(x32**2) + 1e-12)
    fs_mu = q.adc_full_scale(x_rms, jnp.sqrt(jnp.mean(mu.astype(jnp.float32)**2) + 1e-12), qcfg)
    psum_mu = jnp.einsum("bkc,kcn->bkn", xb, mub)
    y_mu = q.adc_quantize(psum_mu, fs_mu, qcfg).sum(axis=1)   # [B, N]

    se = sigma.astype(jnp.float32)[None] * eps                 # [R, K, N]
    seb = se.reshape(num_samples, kc, chunk, n)
    # Host calibration uses rms(σ) (E[ε²]=1), matching kernels/ops.py.
    fs_se = q.adc_full_scale(
        x_rms, jnp.sqrt(jnp.mean(sigma.astype(jnp.float32)**2) + 1e-12), qcfg)
    psum_se = jnp.einsum("bkc,rkcn->rbkn", xb, seb)
    y_se = q.adc_quantize(psum_se, fs_se, qcfg).sum(axis=2)    # [R, B, N]
    return y_mu[None] + y_se


def cim_mvm_ref(x: jnp.ndarray, w: jnp.ndarray, qcfg: q.QuantConfig,
                fs: jnp.ndarray | float) -> jnp.ndarray:
    """Deterministic chunked-ADC MVM oracle with explicit full scale."""
    b, kdim = x.shape
    chunk = qcfg.chunk
    assert kdim % chunk == 0
    kc = kdim // chunk
    xb = x.astype(jnp.float32).reshape(b, kc, chunk)
    wb = w.astype(jnp.float32).reshape(kc, chunk, w.shape[1])
    psum = jnp.einsum("bkc,kcn->bkn", xb, wb)
    return q.adc_quantize(psum, fs, qcfg).sum(axis=1)


def cim_mvm_nonideal_ref(x: jnp.ndarray, w: jnp.ndarray, qcfg: q.QuantConfig,
                         fs: jnp.ndarray | float, col_gain: jnp.ndarray,
                         col_offset: jnp.ndarray) -> jnp.ndarray:
    """Nonideal chunked-ADC oracle (per-column ADC gain + offset).

    Each analog chunk's partial sum is distorted by the column front-end
    before conversion: v = gain[n]·psum + offset[n]·lsb (offset in LSB
    units), then ideally coded and digitally accumulated.  With
    gain = 1, offset = 0 this is bit-identical to ``cim_mvm_ref`` —
    the zero-variation acceptance check for the nonideal kernel path.
    """
    b, kdim = x.shape
    chunk = qcfg.chunk
    assert kdim % chunk == 0
    kc = kdim // chunk
    xb = x.astype(jnp.float32).reshape(b, kc, chunk)
    wb = w.astype(jnp.float32).reshape(kc, chunk, w.shape[1])
    psum = jnp.einsum("bkc,kcn->bkn", xb, wb)
    levels = 2 ** (qcfg.adc_bits - 1) - 1
    lsb = fs / levels
    v = (col_gain.astype(jnp.float32)[None, None] * psum
         + col_offset.astype(jnp.float32)[None, None] * lsb)
    code = jnp.clip(jnp.round(v / lsb), -levels - 1, levels)
    return (code * lsb).sum(axis=1)


def selections_ref(lfsr_seed: int, num_samples: int, sample0: int = 0):
    states = lfsr_states(lfsr_seed, sample0 + num_samples)
    return swapper_select(states[sample0:])


def decision_stats_ref(y_mu: jnp.ndarray, x_sigma: jnp.ndarray,
                       m: jnp.ndarray, sel: jnp.ndarray, cfg: g.GRNGConfig,
                       x_sigsq=None, sample_idx=None, mask=None,
                       rows=None) -> dict:
    """Fused decision-kernel oracle: one round's masked stat deltas.

    The no-blocking ground truth for ``decision_kernel.py`` — it DOES
    materialize the [R, B, N] samples (that is the point: the kernel
    must match the materializing path, then never pay for it).  Sample
    semantics are ``core.sampling.mix_samples`` verbatim (same hash
    stream for degraded-instance read noise, keyed by the absolute
    ``sample_idx``); the statistics are
    ``serving.adaptive.update_stats`` on zeroed running sums:

        logp = log_softmax(samples); p = exp(logp)
        sum_p = Σ_r p, sum_psq = Σ_r p², ent = -Σ_n p·logp,
        sum_ent = Σ_r ent, sum_entsq = Σ_r ent²

    all multiplied by the [B] active-slot ``mask`` (None = all active).
    """
    b, n = y_mu.shape
    if sel.ndim == 2:
        sel = jnp.broadcast_to(sel[:, None, :], (sel.shape[0], b, 16))
    mix = jnp.einsum("rbj,bnj->rbn", sel.astype(jnp.float32),
                     m.astype(jnp.float32))
    out = mix - cfg.sum_mean * x_sigma.astype(jnp.float32)[None]
    if cfg.read_sigma:
        key = jnp.asarray(sample_idx, jnp.uint32)
        if key.ndim == 1:
            key = key[:, None]
        # rows: global slot ids for the hash stream — a shard of a
        # sharded pool passes its global offsets (default: local ids).
        row_ids = (jnp.arange(b, dtype=jnp.uint32) if rows is None
                   else jnp.asarray(rows, jnp.uint32))
        h = hash3(key[..., None],
                  row_ids[None, :, None],
                  jnp.arange(n, dtype=jnp.uint32)[None, None, :],
                  cfg.noise_seed)
        sigma_read = cfg.read_sigma * jnp.sqrt(
            jnp.maximum(x_sigsq.astype(jnp.float32), 0.0))
        out = out + gaussianish(h) * sigma_read[None]
    samples = y_mu.astype(jnp.float32)[None] + out / cfg.sum_std
    logp = jax.nn.log_softmax(samples, axis=-1)
    p = jnp.exp(logp)
    ent = -(p * logp).sum(-1)                            # [R, B]
    mk = (jnp.ones((b,), jnp.float32) if mask is None
          else jnp.asarray(mask).astype(jnp.float32))
    return {"sum_p": p.sum(0) * mk[:, None],
            "sum_psq": (p * p).sum(0) * mk[:, None],
            "sum_ent": ent.sum(0) * mk,
            "sum_entsq": (ent * ent).sum(0) * mk}
