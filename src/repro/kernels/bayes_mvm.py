"""Pallas TPU kernel: fused Bayesian head MVM (the paper's §IV dataflow).

Computes R logit samples  Y_r = X·(µ' + σ⊙ε_r)  for the weight-
decomposition head without ever materializing ε or the sampled weights
in HBM — the TPU analogue of the paper's in-memory σε subarray, where
randomness is generated at the point of compute.

Two variants, selected by the paper's shared-selection structure:

  * ``rank16`` (beyond-paper fast path): per (k-block) we accumulate the
    16 basis matmuls  basis_j += X·(σ⊙I_j)  in VMEM scratch and mix
    them with the [R,16] selection table at the last k step.  Cost is
    independent of R (≈18 MVM-equivalents); the sample distribution is
    *identical* to the faithful path because selection is shared
    layer-wide.  On a degraded chip instance (``cfg.read_sigma > 0``)
    the per-read noise term is full-rank per sample and cannot ride the
    basis; the kernel instead accumulates (x²)·(σ²) alongside and adds
    the exact logit-level projection N(0, read_sigma²·Σ x²σ²) at the
    final k step, hashed from the absolute sample index with the SAME
    stream as core/sampling.mix_samples — kernel-path serving matches
    the engine fast path draw-for-draw, and the faithful ``paper`` path
    in distribution (tests/test_hw_conformance.py).

  * ``paper`` (faithful path, optional 6-bit ADC): ε_r is materialized
    per sample in VMEM and each sample performs its own σε matmul, with
    partial sums optionally digitized every 64 rows (qcfg.chunk) at
    6-bit — the hardware's exact numeric order of operations.

VMEM per grid step (bB=bK=bN=128, R=20, f32):
  rank16: x 64K + µ/σ 128K + basis 16·64K=1M + acc 2·64K + out
          20·64K=1.25M  ≈ 2.6 MB; read_sigma > 0 adds the 64K (x²)(σ²)
          scratch plus an [R, bB, bN] noise-stack temporary in the
          final k step (R·64K ≈ 1.25 MB at R=20 — budget ≈ 3.9 MB on
          degraded instances)
  paper : x 64K + µ/σ 128K + eps 64K + out 1.25M                            ≈ 1.6 MB
Both well inside the ~16 MB v5e VMEM; matmul dims are 128-aligned (MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.clt_grng import GRNGConfig
from repro.core.quant import QuantConfig
from repro.kernels.backend import resolve_interpret
from repro.kernels.clt_grng_kernel import (_device_current, _gauss_of, _hash3,
                                           _read_noise)


# ----------------------------------------------------------------------
# rank16 variant
# ----------------------------------------------------------------------
def _rank16_kernel(x_ref, mu_ref, sig_ref, sel_ref, out_ref,
                   basis_ref, accmu_ref, accxs_ref, *scratch,
                   cfg: GRNGConfig, bb: int, bk: int, bn: int,
                   row0: int, col0: int, sample0: int):
    # The (x²)·(σ²) accumulator exists only on degraded instances — the
    # ideal path (read_sigma == 0) allocates no noise scratch.
    accxq_ref = scratch[0] if cfg.read_sigma else None
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        basis_ref[...] = jnp.zeros_like(basis_ref)
        accmu_ref[...] = jnp.zeros_like(accmu_ref)
        accxs_ref[...] = jnp.zeros_like(accxs_ref)
        if cfg.read_sigma:
            accxq_ref[...] = jnp.zeros_like(accxq_ref)

    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = (jnp.uint32(row0) + kstep * bk
            + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0))
    cols = (jnp.uint32(col0) + j * bn
            + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1))

    x = x_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    sig = sig_ref[...].astype(jnp.float32)

    accmu_ref[...] += jnp.dot(x, mu, preferred_element_type=jnp.float32)
    accxs_ref[...] += jnp.dot(x, sig, preferred_element_type=jnp.float32)
    if cfg.read_sigma:                       # (x²)·(σ²): noise projection
        accxq_ref[...] += jnp.dot(x * x, sig * sig,
                                  preferred_element_type=jnp.float32)
    for d in range(cfg.n_devices):           # 16 basis MVMs, unrolled
        i_d = _device_current(rows, cols, d, cfg)
        basis_ref[d, :, :] += jnp.dot(x, sig * i_d,
                                      preferred_element_type=jnp.float32)

    @pl.when(kstep == pl.num_programs(2) - 1)
    def _finish():
        sel = sel_ref[...]                   # [R, 16]
        basis = basis_ref[...]               # [16, bB, bN]
        mixed = jax.lax.dot_general(
            sel, basis.reshape(cfg.n_devices, -1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(sel.shape[0], *basis.shape[1:])        # [R, bB, bN]
        num = mixed - cfg.sum_mean * accxs_ref[...][None]
        if cfg.read_sigma:                   # degraded-instance twin
            # Per-read noise is full-rank per sample, so it cannot ride
            # the 16 basis MVMs; add its exact logit-level projection
            # N(0, read_sigma²·Σ_k x_k²σ_kn²) instead, drawn from the
            # SAME hash stream as core.sampling.mix_samples
            # (hash3(sample_idx, batch, col)) so kernel-path serving and
            # the engine fast path produce the same noise realization.
            bat = (i * bb
                   + jax.lax.broadcasted_iota(jnp.uint32, (bb, bn), 0))
            ncol = (jnp.uint32(col0) + j * bn
                    + jax.lax.broadcasted_iota(jnp.uint32, (bb, bn), 1))
            sigma_read = cfg.read_sigma * jnp.sqrt(
                jnp.maximum(accxq_ref[...], 0.0))        # [bB, bN]
            noise = jnp.stack([
                _gauss_of(_hash3(jnp.uint32(sample0 + r), bat, ncol,
                                 cfg.noise_seed))
                for r in range(sel.shape[0])])           # [R, bB, bN]
            num = num + noise * sigma_read[None]
        out_ref[...] = accmu_ref[...][None] + num * (1.0 / cfg.sum_std)


# ----------------------------------------------------------------------
# paper-faithful variant (optional chunked 6-bit ADC)
# ----------------------------------------------------------------------
def _paper_kernel(x_ref, mu_ref, sig_ref, sel_ref, fs_ref, out_ref, acc_ref, *,
                  cfg: GRNGConfig, qcfg: QuantConfig | None,
                  bk: int, bn: int, row0: int, col0: int, num_samples: int,
                  sample0: int):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    rows = (jnp.uint32(row0) + kstep * bk
            + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0))
    cols = (jnp.uint32(col0) + j * bn
            + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1))

    x = x_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    sig = sig_ref[...].astype(jnp.float32)
    sel = sel_ref[...]                       # [R, 16]

    currents = [_device_current(rows, cols, d, cfg) for d in range(cfg.n_devices)]

    def adc(psum, fs):
        if qcfg is None:
            return psum
        levels = 2 ** (qcfg.adc_bits - 1) - 1
        lsb = fs / levels
        return jnp.clip(jnp.round(psum / lsb), -levels - 1, levels) * lsb

    def chunked_mvm(w, fs):
        """X·w with ADC digitization every qcfg.chunk rows (hardware order)."""
        if qcfg is None:
            return jnp.dot(x, w, preferred_element_type=jnp.float32)
        acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
        for c0 in range(0, bk, qcfg.chunk):
            psum = jnp.dot(x[:, c0:c0 + qcfg.chunk], w[c0:c0 + qcfg.chunk],
                           preferred_element_type=jnp.float32)
            acc = acc + adc(psum, fs)
        return acc

    fs_mu = fs_ref[0, 0]
    fs_se = fs_ref[0, 1]
    y_mu = chunked_mvm(mu, fs_mu)
    acc_ref[0, :, :] += y_mu
    for r in range(num_samples):             # per-sample σε MVM (faithful)
        raw = jnp.zeros((bk, bn), jnp.float32)
        for d in range(cfg.n_devices):
            raw = raw + sel[r, d] * currents[d]
        if cfg.read_sigma:                   # degraded-instance twin
            raw = raw + _read_noise(rows, cols, sample0 + r, cfg)
        eps_r = (raw - cfg.sum_mean) * (1.0 / cfg.sum_std)
        acc_ref[1 + r, :, :] += chunked_mvm(sig * eps_r, fs_se)

    @pl.when(kstep == pl.num_programs(2) - 1)
    def _finish():
        out_ref[...] = acc_ref[0, :, :][None] + acc_ref[1:, :, :]


# ----------------------------------------------------------------------
# host-side wrappers (padding, grid setup)
# ----------------------------------------------------------------------
def _pad2(a, m0, m1):
    p0, p1 = (-a.shape[0]) % m0, (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=(
    "cfg", "qcfg", "mode", "row0", "col0", "sample0", "bb", "bk", "bn",
    "interpret"))
def bayes_mvm_pallas(x, mu, sigma, sel, fs, cfg: GRNGConfig,
                     qcfg: QuantConfig | None = None, mode: str = "rank16",
                     row0: int = 0, col0: int = 0, sample0: int = 0,
                     bb: int = 128, bk: int = 128, bn: int = 128,
                     interpret: bool | None = None):
    """Fused Bayesian head. x:[B,K], µ/σ:[K,N], sel:[R,16], fs:[1,2].

    Returns [R, B, N] float32 logit samples.  Zero-padding is safe: σ and
    µ pads are zero so padded rows/cols contribute nothing.
    ``interpret=None`` auto-detects the backend (kernels/backend.py):
    compiled on TPU, interpreted elsewhere.
    """
    interpret = resolve_interpret(interpret)
    b, kdim = x.shape
    _, n = mu.shape
    r = sel.shape[0]
    xp = _pad2(x, bb, bk)
    mup = _pad2(mu, bk, bn)
    sigp = _pad2(sigma, bk, bn)
    bp, kp = xp.shape
    np_ = mup.shape[1]
    grid = (bp // bb, np_ // bn, kp // bk)

    if mode == "rank16":
        out = pl.pallas_call(
            functools.partial(_rank16_kernel, cfg=cfg, bb=bb, bk=bk, bn=bn,
                              row0=row0, col0=col0, sample0=sample0),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                pl.BlockSpec((r, 16), lambda i, j, k: (0, 0)),
            ],
            out_specs=pl.BlockSpec((r, bb, bn), lambda i, j, k: (0, i, j)),
            out_shape=jax.ShapeDtypeStruct((r, bp, np_), jnp.float32),
            scratch_shapes=(
                [pltpu.VMEM((cfg.n_devices, bb, bn), jnp.float32),
                 pltpu.VMEM((bb, bn), jnp.float32),
                 pltpu.VMEM((bb, bn), jnp.float32)]
                # (x²)·(σ²) accumulator, degraded instances only
                + ([pltpu.VMEM((bb, bn), jnp.float32)]
                   if cfg.read_sigma else [])),
            interpret=interpret,
        )(xp, mup, sigp, sel)
    elif mode == "paper":
        out = pl.pallas_call(
            functools.partial(_paper_kernel, cfg=cfg, qcfg=qcfg, bk=bk, bn=bn,
                              row0=row0, col0=col0, num_samples=r,
                              sample0=sample0),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                pl.BlockSpec((r, 16), lambda i, j, k: (0, 0)),
                pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
            ],
            out_specs=pl.BlockSpec((r, bb, bn), lambda i, j, k: (0, i, j)),
            out_shape=jax.ShapeDtypeStruct((r, bp, np_), jnp.float32),
            scratch_shapes=[pltpu.VMEM((1 + r, bb, bn), jnp.float32)],
            interpret=interpret,
        )(xp, mup, sigp, sel, fs)
    else:
        raise ValueError(mode)
    return out[:, :b, :n]
