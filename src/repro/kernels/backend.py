"""Backend selection for the Pallas kernels: compile on TPU, interpret
elsewhere.

Every kernel wrapper in this package takes ``interpret: bool | None``.
``None`` (the default everywhere) resolves through ``interpret_default``:
Pallas kernels COMPILE when the active JAX backend is a real TPU and
fall back to interpret mode otherwise (CPU CI, local dev), so TPU runs
stop paying the interpreter cost without any call-site changes.

Override per-process with the environment variable
``REPRO_PALLAS_INTERPRET``:

  * ``1`` / ``true``  — force interpret mode everywhere (debugging a
    kernel on TPU, or double-checking a miscompile),
  * ``0`` / ``false`` — force compiled mode (e.g. Pallas-on-Mosaic-CPU
    experiments),
  * unset / ``auto``  — backend auto-detection (the default).

This module is import-cycle-free on purpose: the kernel modules
(bayes_mvm, cim_mvm, clt_grng_kernel, decision_kernel) import it, and
``kernels/ops.py`` re-exports ``interpret_default`` as the public
helper.
"""

from __future__ import annotations

import os

import jax

_ENV = "REPRO_PALLAS_INTERPRET"
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def interpret_default() -> bool:
    """Resolve the interpret-mode default for a Pallas kernel call.

    Env override first (``REPRO_PALLAS_INTERPRET``), then backend
    auto-detection: interpret unless running on real TPU hardware.
    """
    raw = os.environ.get(_ENV, "auto").strip().lower()
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``interpret`` if explicitly given, else ``interpret_default()``."""
    return interpret_default() if interpret is None else bool(interpret)
