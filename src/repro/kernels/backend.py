"""Backend selection for the Pallas kernels: compile on TPU, interpret
elsewhere.

Every kernel wrapper in this package takes ``interpret: bool | None``.
``None`` (the default everywhere) resolves through ``interpret_default``:
Pallas kernels COMPILE when the active JAX backend is a real TPU and
fall back to interpret mode otherwise (CPU CI, local dev), so TPU runs
stop paying the interpreter cost without any call-site changes.

Resolution precedence, highest first:

  1. **Per-call argument** — an explicit ``interpret=True/False`` passed
     to a kernel wrapper always wins.  The shard_map-native decision
     kernel resolves the flag *once* at the wrapper level and passes the
     concrete bool into every shard, so all shards of one call lower
     identically regardless of ambient state.
  2. **Scoped override** — ``with interpret_override(True/False): ...``
     pins the mode for every kernel call (with ``interpret=None``) in
     the dynamic extent.  Used to force compile/interpret per shard or
     per benchmark arm without threading a flag through every layer.
  3. **Environment** — ``REPRO_PALLAS_INTERPRET``:
     ``1``/``true`` force interpret everywhere (debugging a kernel on
     TPU, double-checking a miscompile); ``0``/``false`` force compiled
     mode (Pallas-on-Mosaic-CPU experiments); unset/``auto`` falls
     through.
  4. **Backend auto-detect** — interpret unless the active JAX backend
     is a real TPU.

This module is import-cycle-free on purpose: the kernel modules
(bayes_mvm, cim_mvm, clt_grng_kernel, decision_kernel) import it, and
``kernels/ops.py`` re-exports ``interpret_default`` as the public
helper.
"""

from __future__ import annotations

import contextlib
import os
import threading

import jax

_ENV = "REPRO_PALLAS_INTERPRET"
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")

_local = threading.local()


def interpret_default() -> bool:
    """Resolve the interpret-mode default for a Pallas kernel call.

    Scoped ``interpret_override`` first, then the env override
    (``REPRO_PALLAS_INTERPRET``), then backend auto-detection:
    interpret unless running on real TPU hardware.
    """
    override = getattr(_local, "override", None)
    if override is not None:
        return override
    raw = os.environ.get(_ENV, "auto").strip().lower()
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    return jax.default_backend() != "tpu"


@contextlib.contextmanager
def interpret_override(value: bool | None):
    """Pin interpret mode for kernel calls in this dynamic extent.

    ``True``/``False`` force the mode for every kernel invoked with
    ``interpret=None``; ``None`` restores auto resolution.  Overrides
    nest (innermost wins) and are thread-local, so concurrent benches
    don't bleed into each other.  An explicit per-call ``interpret=``
    argument still beats the override — see the module docstring for
    the full precedence.
    """
    prev = getattr(_local, "override", None)
    _local.override = None if value is None else bool(value)
    try:
        yield
    finally:
        _local.override = prev


def resolve_interpret(interpret: bool | None) -> bool:
    """``interpret`` if explicitly given, else ``interpret_default()``."""
    return interpret_default() if interpret is None else bool(interpret)
