"""Pallas TPU kernel: deterministic CIM MVM (µ-only subarray, paper §V-B1).

The paper maps all deterministic layers onto µ-only subarrays via
im2col.  The numeric path is: 8-bit weights/inputs, analog 64-product
column sums, 6-bit SAR ADC per chunk, digital accumulation.  This
kernel reproduces that inside a 128-aligned blocked matmul: each k-block
contains bk/64 ADC chunks that are digitized *before* joining the
VMEM accumulator.

Inputs are pre-(fake)quantized dequant values; the ADC full-scale is a
runtime scalar (calibrated from activation/weight RMS on the host).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantConfig


def _cim_kernel(x_ref, w_ref, fs_ref, o_ref, acc_ref, *,
                qcfg: QuantConfig, bk: int):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    fs = fs_ref[0, 0]
    levels = 2 ** (qcfg.adc_bits - 1) - 1
    lsb = fs / levels

    for c0 in range(0, bk, qcfg.chunk):      # analog chunks, unrolled
        psum = jnp.dot(x[:, c0:c0 + qcfg.chunk], w[c0:c0 + qcfg.chunk],
                       preferred_element_type=jnp.float32)
        code = jnp.clip(jnp.round(psum / lsb), -levels - 1, levels)
        acc_ref[...] += code * lsb

    @pl.when(kstep == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("qcfg", "bb", "bk", "bn",
                                             "interpret"))
def cim_mvm_pallas(x, w, fs, qcfg: QuantConfig,
                   bb: int = 128, bk: int = 128, bn: int = 128,
                   interpret: bool = True):
    """Chunked-ADC MVM. x:[B,K], w:[K,N], fs:[1,1] -> [B,N] float32.

    K must be a multiple of qcfg.chunk (the physical tile depth); B and N
    are zero-padded to block multiples.  Zero pads are ADC-safe: a zero
    partial sum quantizes to code 0.
    """
    b, kdim = x.shape
    n = w.shape[1]
    assert kdim % qcfg.chunk == 0, "K must be chunk-aligned (tile depth)"
    assert bk % qcfg.chunk == 0
    pb, pk, pn = (-b) % bb, (-kdim) % bk, (-n) % bn
    xp = jnp.pad(x, ((0, pb), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    bp, kp = xp.shape
    np_ = wp.shape[1]
    out = pl.pallas_call(
        functools.partial(_cim_kernel, qcfg=qcfg, bk=bk),
        grid=(bp // bb, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, fs)
    return out[:b, :n]
