"""Pallas TPU kernel: deterministic CIM MVM (µ-only subarray, paper §V-B1).

The paper maps all deterministic layers onto µ-only subarrays via
im2col.  The numeric path is: 8-bit weights/inputs, analog 64-product
column sums, 6-bit SAR ADC per chunk, digital accumulation.  This
kernel reproduces that inside a 128-aligned blocked matmul: each k-block
contains bk/64 ADC chunks that are digitized *before* joining the
VMEM accumulator.

Inputs are pre-(fake)quantized dequant values; the ADC full-scale is a
runtime scalar (calibrated from activation/weight RMS on the host).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantConfig
from repro.kernels.backend import resolve_interpret


def _cim_kernel(x_ref, w_ref, fs_ref, gain_ref, off_ref, o_ref, acc_ref, *,
                qcfg: QuantConfig, bk: int, n_real_chunks: int):
    """Chunked-ADC MVM with per-column ADC front-end nonideality.

    The bitline/SAR front-end of physical column n distorts the analog
    partial sum *before* conversion:  v = gain[n]·psum + offset[n]·lsb
    (gain error from capacitor-DAC mismatch, offset in LSB units from
    comparator offset — the repro/hw chip-instance model).  The digital
    side interprets codes ideally, so gain=1/offset=0 is bit-identical
    to the ideal path.

    K-padding chunks beyond ``n_real_chunks`` are masked out entirely:
    a pad chunk has no physical conversion, so it must not pick up the
    comparator offset (with offset=0 its code is 0 anyway).
    """
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    fs = fs_ref[0, 0]
    gain = gain_ref[...]                     # [1, bn]
    off = off_ref[...]                       # [1, bn]
    levels = 2 ** (qcfg.adc_bits - 1) - 1
    lsb = fs / levels
    kchunks = bk // qcfg.chunk

    for ci, c0 in enumerate(range(0, bk, qcfg.chunk)):   # chunks, unrolled
        psum = jnp.dot(x[:, c0:c0 + qcfg.chunk], w[c0:c0 + qcfg.chunk],
                       preferred_element_type=jnp.float32)
        v = gain * psum + off * lsb
        code = jnp.clip(jnp.round(v / lsb), -levels - 1, levels)
        real = kstep * kchunks + ci < n_real_chunks
        acc_ref[...] += jnp.where(real, code * lsb, 0.0)

    @pl.when(kstep == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("qcfg", "bb", "bk", "bn",
                                             "interpret"))
def cim_mvm_pallas(x, w, fs, qcfg: QuantConfig,
                   col_gain=None, col_offset=None,
                   bb: int = 128, bk: int = 128, bn: int = 128,
                   interpret: bool | None = None):
    """Chunked-ADC MVM. x:[B,K], w:[K,N], fs:[1,1] -> [B,N] float32.

    K must be a multiple of qcfg.chunk (the physical tile depth); B and N
    are zero-padded to block multiples.  Zero pads are ADC-safe: a zero
    partial sum quantizes to code 0 (gain scales zero to zero; the pad
    columns' gain/offset pads are 1/0).

    col_gain/col_offset: optional [N] per-column ADC gain and offset
    (offset in LSB units) — the nonideal chip-instance path.  Omitted =
    ideal ADC (bit-identical to the previous behaviour).
    """
    interpret = resolve_interpret(interpret)
    b, kdim = x.shape
    n = w.shape[1]
    assert kdim % qcfg.chunk == 0, "K must be chunk-aligned (tile depth)"
    assert bk % qcfg.chunk == 0
    pb, pk, pn = (-b) % bb, (-kdim) % bk, (-n) % bn
    xp = jnp.pad(x, ((0, pb), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    if col_gain is None:
        col_gain = jnp.ones((n,), jnp.float32)
    if col_offset is None:
        col_offset = jnp.zeros((n,), jnp.float32)
    gp = jnp.pad(col_gain.astype(jnp.float32).reshape(1, n),
                 ((0, 0), (0, pn)), constant_values=1.0)
    op = jnp.pad(col_offset.astype(jnp.float32).reshape(1, n),
                 ((0, 0), (0, pn)))
    bp, kp = xp.shape
    np_ = wp.shape[1]
    out = pl.pallas_call(
        functools.partial(_cim_kernel, qcfg=qcfg, bk=bk,
                          n_real_chunks=kdim // qcfg.chunk),
        grid=(bp // bb, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, fs, gp, op)
    return out[:b, :n]
