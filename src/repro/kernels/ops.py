"""Public jit'd entry points for the kernels package.

These wrappers own host-side concerns: selection-table generation,
ADC full-scale calibration, dtype plumbing, and the interpret-mode
default (interpret=True unless running on real TPU).  They are the
drop-in counterparts of the pure-jnp paths in core/sampling.py and
core/cim.py, asserted allclose in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import clt_grng as g
from repro.core.quant import QuantConfig, adc_full_scale
from repro.kernels.bayes_mvm import bayes_mvm_pallas
from repro.kernels.cim_mvm import cim_mvm_pallas
from repro.kernels.clt_grng_kernel import grng_eps_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def grng_eps(cfg: g.GRNGConfig, n_rows: int, n_cols: int, num_samples: int,
             sample0: int = 0, row0: int = 0, col0: int = 0,
             interpret: bool | None = None) -> jnp.ndarray:
    """CLT-GRNG ε block via the Pallas kernel. -> [R, n_rows, n_cols]."""
    sel = g.selections(cfg, num_samples, sample0)
    bk = min(256, max(128, n_rows))
    bn = min(256, max(128, n_cols))
    return grng_eps_pallas(
        sel, cfg, n_rows, n_cols, row0=row0, col0=col0, sample0=sample0,
        bk=bk, bn=bn,
        interpret=_interpret_default() if interpret is None else interpret)


def bayes_head_mvm(x: jnp.ndarray, mu_prime: jnp.ndarray, sigma: jnp.ndarray,
                   cfg: g.GRNGConfig, num_samples: int, sample0: int = 0,
                   mode: str = "rank16", qcfg: QuantConfig | None = None,
                   row0: int = 0, col0: int = 0,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Fused Bayesian head: [R, B, N] logit samples.

    mode='rank16'  — R-independent fast path (exact distribution).  On a
                     degraded instance (``cfg.read_sigma > 0``) it adds
                     the logit-level read-noise projection, matching the
                     core/sampling.mix_samples hash stream draw-for-draw
                     (oracle: ref.bayes_mvm_rank16_ref).
    mode='paper'   — faithful per-sample path (per-cell read noise);
                     pass qcfg to enable the 6-bit chunked-ADC numeric
                     pipeline.
    """
    sel = g.selections(cfg, num_samples, sample0)
    if qcfg is not None and not qcfg.enabled:
        qcfg = None
    if qcfg is not None:
        assert mode == "paper", "ADC path requires hardware sample order"
        x_rms = jnp.sqrt(jnp.mean(x.astype(jnp.float32) ** 2) + 1e-12)
        mu_rms = jnp.sqrt(jnp.mean(mu_prime.astype(jnp.float32) ** 2) + 1e-12)
        # σε RMS: Var[σ·ε] ≈ E[σ²] for standardized ε.
        se_rms = jnp.sqrt(jnp.mean(sigma.astype(jnp.float32) ** 2) + 1e-12)
        fs = jnp.stack([adc_full_scale(x_rms, mu_rms, qcfg),
                        adc_full_scale(x_rms, se_rms, qcfg)]).reshape(1, 2)
    else:
        fs = jnp.zeros((1, 2), jnp.float32)
    return bayes_mvm_pallas(
        x, mu_prime, sigma, sel, fs, cfg, qcfg=qcfg, mode=mode,
        row0=row0, col0=col0, sample0=sample0,
        interpret=_interpret_default() if interpret is None else interpret)


def _measured_full_scale(x, w, qcfg: QuantConfig):
    """One-time ADC range calibration from measured partial-sum RMS
    (sampled rows for cost) — see core/cim.py for why the analytic
    independence model under-scales."""
    xs = x[: min(16, x.shape[0])].astype(jnp.float32)
    kc = x.shape[1] // qcfg.chunk
    xb = xs.reshape(xs.shape[0], kc, qcfg.chunk)
    wb = w.astype(jnp.float32).reshape(kc, qcfg.chunk, w.shape[1])
    ps = jnp.einsum("bkc,kcn->bkn", xb, wb)
    return qcfg.adc_clip_sigmas * jnp.sqrt(jnp.mean(ps ** 2) + 1e-12)


def cim_matmul(x: jnp.ndarray, w: jnp.ndarray, qcfg: QuantConfig,
               interpret: bool | None = None) -> jnp.ndarray:
    """Deterministic chunked-ADC CIM matmul (µ-only subarray)."""
    fs = _measured_full_scale(x, w, qcfg).reshape(1, 1)
    return cim_mvm_pallas(
        x, w, fs, qcfg,
        interpret=_interpret_default() if interpret is None else interpret)


def cim_matmul_nonideal(x: jnp.ndarray, w: jnp.ndarray, qcfg: QuantConfig,
                        col_gain: jnp.ndarray, col_offset: jnp.ndarray,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Chip-instance CIM matmul: per-column ADC gain/offset (repro/hw).

    ``col_gain``/``col_offset`` [N] come from a sampled ChipInstance
    (hw/instance.py: ``adc_gain``/``adc_offset`` tiled over the output
    columns).  Conductance programming error is a *weight* perturbation —
    fold it into ``w`` with ``hw.instance.program_weights`` before the
    call.  Oracle: kernels/ref.cim_mvm_nonideal_ref.
    """
    fs = _measured_full_scale(x, w, qcfg).reshape(1, 1)
    return cim_mvm_pallas(
        x, w, fs, qcfg, col_gain=col_gain, col_offset=col_offset,
        interpret=_interpret_default() if interpret is None else interpret)
