"""Public jit'd entry points for the kernels package.

These wrappers own host-side concerns: selection-table generation,
ADC full-scale calibration, dtype plumbing, and the interpret-mode
default (``interpret_default``: compile on TPU, interpret elsewhere,
env-overridable — kernels/backend.py).  They are the drop-in
counterparts of the pure-jnp paths in core/sampling.py and core/cim.py,
asserted allclose in tests/test_kernels.py.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import clt_grng as g
from repro.core.quant import QuantConfig, adc_full_scale
# Public backend helper (implemented cycle-free in kernels/backend.py).
from repro.kernels.backend import interpret_default  # noqa: F401
from repro.kernels.bayes_mvm import bayes_mvm_pallas
from repro.kernels.cim_mvm import cim_mvm_pallas
from repro.kernels.clt_grng_kernel import grng_eps_pallas
from repro.kernels.decision_kernel import (decision_stats_pallas,
                                           decision_stats_sharded)


def grng_eps(cfg: g.GRNGConfig, n_rows: int, n_cols: int, num_samples: int,
             sample0: int = 0, row0: int = 0, col0: int = 0,
             interpret: bool | None = None) -> jnp.ndarray:
    """CLT-GRNG ε block via the Pallas kernel. -> [R, n_rows, n_cols]."""
    sel = g.selections(cfg, num_samples, sample0)
    bk = min(256, max(128, n_rows))
    bn = min(256, max(128, n_cols))
    return grng_eps_pallas(
        sel, cfg, n_rows, n_cols, row0=row0, col0=col0, sample0=sample0,
        bk=bk, bn=bn, interpret=interpret)


def bayes_head_mvm(x: jnp.ndarray, mu_prime: jnp.ndarray, sigma: jnp.ndarray,
                   cfg: g.GRNGConfig, num_samples: int, sample0: int = 0,
                   mode: str = "rank16", qcfg: QuantConfig | None = None,
                   row0: int = 0, col0: int = 0,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Fused Bayesian head: [R, B, N] logit samples.

    mode='rank16'  — R-independent fast path (exact distribution).  On a
                     degraded instance (``cfg.read_sigma > 0``) it adds
                     the logit-level read-noise projection, matching the
                     core/sampling.mix_samples hash stream draw-for-draw
                     (oracle: ref.bayes_mvm_rank16_ref).
    mode='paper'   — faithful per-sample path (per-cell read noise);
                     pass qcfg to enable the 6-bit chunked-ADC numeric
                     pipeline.
    """
    sel = g.selections(cfg, num_samples, sample0)
    if qcfg is not None and not qcfg.enabled:
        qcfg = None
    if qcfg is not None:
        assert mode == "paper", "ADC path requires hardware sample order"
        x_rms = jnp.sqrt(jnp.mean(x.astype(jnp.float32) ** 2) + 1e-12)
        mu_rms = jnp.sqrt(jnp.mean(mu_prime.astype(jnp.float32) ** 2) + 1e-12)
        # σε RMS: Var[σ·ε] ≈ E[σ²] for standardized ε.
        se_rms = jnp.sqrt(jnp.mean(sigma.astype(jnp.float32) ** 2) + 1e-12)
        fs = jnp.stack([adc_full_scale(x_rms, mu_rms, qcfg),
                        adc_full_scale(x_rms, se_rms, qcfg)]).reshape(1, 2)
    else:
        fs = jnp.zeros((1, 2), jnp.float32)
    return bayes_mvm_pallas(
        x, mu_prime, sigma, sel, fs, cfg, qcfg=qcfg, mode=mode,
        row0=row0, col0=col0, sample0=sample0, interpret=interpret)


def decision_update(stats: dict, abasis: dict, sel: jnp.ndarray,
                    cfg: g.GRNGConfig, sample_idx=None, mask=None,
                    interpret: bool | None = None, shard=None,
                    rows=None) -> dict:
    """Fused drop-in for ``update_stats(stats, mix_samples(...), mask)``.

    Folds one escalation round into the running sufficient statistics
    via the fused decision kernel (decision_kernel.py): mixing, the
    degraded-instance read-noise projection, online softmax over N,
    entropy, and the active-slot masking all run in VMEM — the [R,B,N]
    logit-sample tensor never exists.

    stats: ``adaptive.init_stats`` pytree; abasis:
    ``core.sampling.activation_basis`` output; sel: [R, B, 16] or
    [R, 16]; sample_idx: absolute stream indices ([R, B] or [R],
    ``adaptive.stream_indices``) — the read-noise key on degraded
    instances; mask: [B] bool, False rows keep their old sums.

    shard: optional ``(mesh, axis_name)`` — route the round through the
    shard_map-native kernel (``decision_stats_sharded``): each device
    runs its own Pallas grid on its slot shard, stats stay slot-local,
    and ``rows`` ([B] uint32 global slot ids, default ``arange(B)``)
    keys the read-noise hash so sharded draws are bit-identical to the
    single-device stream.

    Verdict-equivalent to the jnp path (tests/test_decision_kernel.py);
    numerics agree to fp32 tolerance (online vs one-shot logsumexp
    reduction order).
    """
    if shard is not None:
        mesh, axis = shard
        delta = decision_stats_sharded(
            abasis["y_mu"], abasis["x_sigma"], abasis["m"], sel, cfg,
            mesh=mesh, axis=axis, x_sigsq=abasis.get("x_sigsq"),
            sample_idx=sample_idx, mask=mask, rows=rows,
            interpret=interpret)
    else:
        delta = decision_stats_pallas(
            abasis["y_mu"], abasis["x_sigma"], abasis["m"], sel, cfg,
            x_sigsq=abasis.get("x_sigsq"), sample_idx=sample_idx, mask=mask,
            rows=rows, interpret=interpret)
    r = sel.shape[0]
    n_delta = jnp.full_like(stats["n"], r)
    if mask is not None:
        n_delta = jnp.where(jnp.asarray(mask), n_delta, 0)
    return {
        "n": stats["n"] + n_delta,
        "sum_p": stats["sum_p"] + delta["sum_p"],
        "sum_psq": stats["sum_psq"] + delta["sum_psq"],
        "sum_ent": stats["sum_ent"] + delta["sum_ent"],
        "sum_entsq": stats["sum_entsq"] + delta["sum_entsq"],
    }


def _measured_full_scale(x, w, qcfg: QuantConfig):
    """One-time ADC range calibration from measured partial-sum RMS
    (sampled rows for cost) — see core/cim.py for why the analytic
    independence model under-scales."""
    xs = x[: min(16, x.shape[0])].astype(jnp.float32)
    kc = x.shape[1] // qcfg.chunk
    xb = xs.reshape(xs.shape[0], kc, qcfg.chunk)
    wb = w.astype(jnp.float32).reshape(kc, qcfg.chunk, w.shape[1])
    ps = jnp.einsum("bkc,kcn->bkn", xb, wb)
    return qcfg.adc_clip_sigmas * jnp.sqrt(jnp.mean(ps ** 2) + 1e-12)


def cim_matmul(x: jnp.ndarray, w: jnp.ndarray, qcfg: QuantConfig,
               interpret: bool | None = None) -> jnp.ndarray:
    """Deterministic chunked-ADC CIM matmul (µ-only subarray)."""
    fs = _measured_full_scale(x, w, qcfg).reshape(1, 1)
    return cim_mvm_pallas(x, w, fs, qcfg, interpret=interpret)


def cim_matmul_nonideal(x: jnp.ndarray, w: jnp.ndarray, qcfg: QuantConfig,
                        col_gain: jnp.ndarray, col_offset: jnp.ndarray,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Chip-instance CIM matmul: per-column ADC gain/offset (repro/hw).

    ``col_gain``/``col_offset`` [N] come from a sampled ChipInstance
    (hw/instance.py: ``adc_gain``/``adc_offset`` tiled over the output
    columns).  Conductance programming error is a *weight* perturbation —
    fold it into ``w`` with ``hw.instance.program_weights`` before the
    call.  Oracle: kernels/ref.cim_mvm_nonideal_ref.
    """
    fs = _measured_full_scale(x, w, qcfg).reshape(1, 1)
    return cim_mvm_pallas(x, w, fs, qcfg, col_gain=col_gain,
                          col_offset=col_offset, interpret=interpret)
