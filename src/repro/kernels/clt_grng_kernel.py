"""Pallas TPU kernel: block CLT-GRNG ε generation.

Generates the standardized subset-sum samples ε[r, k, n] for a weight
block entirely on-chip: virtual device currents are re-derived from the
integer hash of the (row, col, device) coordinate (write-free — zero
HBM traffic for randomness), masked by the shared selection vectors and
summed.  The only HBM input is the [R, 16] selection table (64·R bytes);
the output block never round-trips intermediate state.

VMEM budget per grid step (defaults bK=bN=256, R≤32):
  out block  R·256·256·4  ≤ 8 MB @ R=32  (use bK=bN=128 for large R)
  hash temporaries 256·256·4 ≈ 0.25 MB ×3
Matmul-free: the j-loop is 16 unrolled fused multiply-adds on the VPU.
MXU alignment: block dims are multiples of 128 on the minor axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.clt_grng import GRNGConfig
from repro.kernels.backend import resolve_interpret

_C1 = 0x9E3779B9
_C2 = 0x85EBCA6B
_C3 = 0xC2B2AE35


def _mix32(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _hash3(k, n, j, seed: int):
    # Explicit uint32 coercion: program_id-derived indices arrive as
    # int32, and int32 hash arithmetic diverges (arithmetic >> shifts).
    k = jnp.asarray(k).astype(jnp.uint32)
    n = jnp.asarray(n).astype(jnp.uint32)
    j = jnp.asarray(j).astype(jnp.uint32)
    h = _mix32(j * jnp.uint32(_C3) + jnp.uint32(seed))
    h = _mix32(n * jnp.uint32(_C2) + h)
    h = _mix32(k * jnp.uint32(_C1) + h)
    return h


def _gauss_of(h):
    """CLT-of-bytes normal surrogate (core.hashing.gaussianish, inlined)."""
    b0 = (h & jnp.uint32(0xFF)).astype(jnp.float32)
    b1 = ((h >> jnp.uint32(8)) & jnp.uint32(0xFF)).astype(jnp.float32)
    b2 = ((h >> jnp.uint32(16)) & jnp.uint32(0xFF)).astype(jnp.float32)
    return (b0 + b1 + b2 - 382.5) * (1.0 / 127.99316)


def _device_current(rows, cols, j: int, cfg: GRNGConfig):
    """Virtual device current I(k, n, j) for a coordinate block."""
    h = _hash3(rows, cols, j, cfg.seed)
    bit = ((h >> jnp.uint32(31)) & jnp.uint32(1)).astype(jnp.float32)
    out = cfg.i_lo + cfg.delta_i * bit + cfg.gamma * _gauss_of(h)
    if cfg.imprint:                          # aged-die twin (hw/aging)
        out = out + cfg.imprint * _gauss_of(
            _hash3(rows, cols, j, cfg.imprint_seed))
    return out


def _read_noise(rows, cols, r_abs: int, cfg: GRNGConfig):
    """Cycle-to-cycle read noise at absolute sample index ``r_abs`` —
    bit-identical to core.clt_grng.read_noise_at."""
    h = _hash3(rows, cols, r_abs, cfg.noise_seed)
    return cfg.read_sigma * _gauss_of(h)


def _grng_kernel(sel_ref, out_ref, *, cfg: GRNGConfig, bk: int, bn: int,
                 row0: int, col0: int, sample0: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = (jnp.uint32(row0) + i * bk
            + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0))
    cols = (jnp.uint32(col0) + j * bn
            + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1))
    sel = sel_ref[...]                       # [R, 16]
    r = sel.shape[0]
    raw = jnp.zeros((r, bk, bn), jnp.float32)
    for d in range(cfg.n_devices):           # 16, unrolled
        i_d = _device_current(rows, cols, d, cfg)          # [bk, bn]
        raw = raw + sel[:, d][:, None, None] * i_d[None]
    if cfg.read_sigma:                       # degraded-instance twin
        raw = raw + jnp.stack([_read_noise(rows, cols, sample0 + ri, cfg)
                               for ri in range(r)])
    out_ref[...] = (raw - cfg.sum_mean) * (1.0 / cfg.sum_std)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "n_rows", "n_cols", "row0", "col0", "sample0", "bk", "bn",
    "interpret"))
def grng_eps_pallas(sel: jnp.ndarray, cfg: GRNGConfig, n_rows: int,
                    n_cols: int, row0: int = 0, col0: int = 0,
                    sample0: int = 0, bk: int = 256, bn: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """ε block via Pallas. sel: [R, 16] float32 -> [R, n_rows, n_cols].

    ``sample0``: absolute index of sel[0] in the selection stream — only
    read (for the noise hash) when ``cfg.read_sigma > 0``.
    ``interpret=None`` auto-detects the backend (kernels/backend.py).
    """
    interpret = resolve_interpret(interpret)
    r = sel.shape[0]
    pad_k = (-n_rows) % bk
    pad_n = (-n_cols) % bn
    kp, np_ = n_rows + pad_k, n_cols + pad_n
    out = pl.pallas_call(
        functools.partial(_grng_kernel, cfg=cfg, bk=bk, bn=bn,
                          row0=row0, col0=col0, sample0=sample0),
        grid=(kp // bk, np_ // bn),
        in_specs=[pl.BlockSpec((r, 16), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((r, bk, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((r, kp, np_), jnp.float32),
        interpret=interpret,
    )(sel)
    return out[:, :n_rows, :n_cols]
