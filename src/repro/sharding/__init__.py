from repro.sharding.specs import (batch_specs, cache_specs, dp_axes,
                                  logits_spec, opt_state_specs, param_specs,
                                  to_named, validate_specs)

__all__ = ["batch_specs", "cache_specs", "dp_axes", "logits_spec",
           "opt_state_specs", "param_specs", "to_named", "validate_specs"]
