"""Sharding rules: parameter / optimizer / activation / cache specs.

Mesh axes: ('pod', 'data', 'model') multi-pod, ('data', 'model') single
pod.  Conventions (DESIGN.md §8):

  * batch dims            -> ('pod','data')   [DP across pods + hosts]
  * weight "in" dims      -> 'data'           [FSDP / ZeRO: weights and
                                               Adam moments sharded]
  * weight "out"/TP dims  -> 'model'          [Megatron-style TP: heads,
                                               d_ff, experts, vocab]
  * KV-cache sequence dim -> 'model'          [SP: the cache is the
                                               dominant decode tensor;
                                               sharding S keeps kv-head-
                                               count restrictions out of
                                               the memory equation]
  * SSM state             -> heads (or headdim) on 'model'

Every rule degrades gracefully: a dim is only sharded when the axis size
divides it (``_ok``); otherwise the next candidate dim is tried, then
replication.  This is what lets one rule set serve vocab=50280 and
kv_heads=4 alongside 128-expert MoEs on the same 512-chip mesh.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.utils.trees import tree_map_with_name


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def dp_axes(mesh: Mesh):
    """The data-parallel batch axes present in this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _ok(dim: int, mesh: Mesh, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def _spec2(shape, mesh, in_axis="data", out_axis="model", lead=0):
    """[lead..., in, out] weight spec with divisibility fallback."""
    dims = [None] * lead
    d_in, d_out = shape[lead], shape[lead + 1]
    dims.append(in_axis if _ok(d_in, mesh, in_axis) else None)
    dims.append(out_axis if _ok(d_out, mesh, out_axis) else None)
    return P(*dims)


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------
def param_spec_for(name: str, shape: tuple, mesh: Mesh) -> P:
    """Single-leaf rule. ``name`` is the slash path in the params tree;
    leading dim may be a scanned layer stack (handled via ``lead``)."""
    lead = 1 if re.search(r"(^|/)(blocks|blocks_cross|mamba|encoder/blocks)/",
                          name) else 0
    base = name.rsplit("/", 1)[-1]

    # --- MoE experts -------------------------------------------------
    if re.search(r"moe/(wi|wg|wo)$", name):
        e, d1, d2 = shape[lead], shape[lead + 1], shape[lead + 2]
        if _ok(e, mesh, "model"):                 # expert parallelism
            dims = [None] * lead + ["model",
                                    "data" if _ok(d1, mesh, "data") else None,
                                    None]
        else:                                     # TP inside experts
            if base == "wo":                      # [E, F, D]
                dims = [None] * lead + [None,
                                        "model" if _ok(d1, mesh, "model") else None,
                                        "data" if _ok(d2, mesh, "data") else None]
            else:                                 # [E, D, F]
                dims = [None] * lead + [None,
                                        "data" if _ok(d1, mesh, "data") else None,
                                        "model" if _ok(d2, mesh, "model") else None]
        return P(*dims)
    if base == "router":
        return _spec2(shape, mesh, "data", None, lead)

    # --- attention / mlp ---------------------------------------------
    if base in ("wq", "wk", "wv", "wi", "wg"):
        return _spec2(shape, mesh, "data", "model", lead)
    if base in ("wo", "out_proj"):
        return _spec2(shape, mesh, "model", "data", lead)
    if base in ("bq", "bk", "bv", "bi"):
        d = shape[lead]
        return P(*([None] * lead + ["model" if _ok(d, mesh, "model") else None]))

    # --- embeddings / head -------------------------------------------
    if base == "embed":
        return _spec2(shape, mesh, "model", "data", 0)     # [V, D]
    if name.startswith("head/") or "/head/" in name:
        return _spec2(shape, mesh, "data", "model", 0)     # [D, Vp]
    if base == "pos_embed":
        s, d = shape
        return P("data" if _ok(s, mesh, "data") else None, None)

    # --- mamba ---------------------------------------------------------
    if base == "in_proj":
        return _spec2(shape, mesh, "data", "model", lead)
    if base == "conv_w":
        c = shape[lead]
        return P(*([None] * lead
                   + ["model" if _ok(c, mesh, "model") else None, None]))
    if base in ("conv_b", "norm"):
        c = shape[lead]
        return P(*([None] * lead + ["model" if _ok(c, mesh, "model") else None]))
    if base in ("shared_w_in", "shared_w_out"):
        return _spec2(shape, mesh, "data", "model", 0)

    # --- everything else (norm scales, small vectors): replicate ------
    return P(*([None] * len(shape)))


def param_specs(abstract_params: Any, mesh: Mesh):
    return tree_map_with_name(
        lambda name, leaf: param_spec_for(name, tuple(leaf.shape), mesh),
        abstract_params)


def opt_state_specs(abstract_opt: Any, mesh: Mesh):
    """Adam moments shard exactly like their parameters."""
    def rule(name, leaf):
        if name.endswith("count") or leaf.ndim == 0:
            return P()
        # strip the leading "mu/" or "nu/" prefix to reuse param rules
        stripped = name.split("/", 1)[1] if "/" in name else name
        return param_spec_for(stripped, tuple(leaf.shape), mesh)
    return tree_map_with_name(rule, abstract_opt)


# ----------------------------------------------------------------------
# Activations / batches / caches
# ----------------------------------------------------------------------
def batch_specs(abstract_batch: Any, mesh: Mesh):
    """tokens/labels [B, S] -> P(dp, None); stub embeddings likewise."""
    dp = dp_axes(mesh)

    def rule(name, leaf):
        b = leaf.shape[0]
        first = dp if _ok(b, mesh, dp) else (
            "data" if _ok(b, mesh, "data") else None)
        return P(first, *([None] * (leaf.ndim - 1)))

    return tree_map_with_name(rule, abstract_batch)


def cache_specs(abstract_cache: Any, mesh: Mesh):
    """KV caches: batch->dp, sequence->'model' (SP); SSM state: heads (or
    headdim) -> 'model'; conv state: channels -> 'model'."""
    dp = dp_axes(mesh)

    def rule(name, leaf):
        shape = leaf.shape
        if leaf.ndim == 0:      # pos counter
            return P()
        base = name.rsplit("/", 1)[-1]
        if base in ("k", "v", "xk", "xv"):
            # [L, B, S, Hkv, dh] (or [G, ...])
            l_, b, s, hkv, dh = shape
            bax = dp if _ok(b, mesh, dp) else (
                "data" if _ok(b, mesh, "data") else None)
            sax = "model" if _ok(s, mesh, "model") else None
            return P(None, bax, sax, None, None)
        if base == "ssm":
            # [L, B, H, Pd, N]
            l_, b, h, pd, n = shape
            bax = dp if _ok(b, mesh, dp) else (
                "data" if _ok(b, mesh, "data") else None)
            if _ok(h, mesh, "model"):
                return P(None, bax, "model", None, None)
            if _ok(pd, mesh, "model"):
                return P(None, bax, None, "model", None)
            return P(None, bax, None, None, None)
        if base == "conv":
            # [L, B, C, K-1]
            l_, b, c, k = shape
            bax = dp if _ok(b, mesh, dp) else (
                "data" if _ok(b, mesh, "data") else None)
            cax = "model" if _ok(c, mesh, "model") else None
            return P(None, bax, cax, None)
        return P(*([None] * leaf.ndim))

    return tree_map_with_name(rule, abstract_cache)


def logits_spec(mesh: Mesh, batch: int, with_samples: bool = True):
    """[R, B, Vp] logit samples: batch on dp, vocab on model."""
    dp = dp_axes(mesh)
    bax = dp if batch % _axis_size(mesh, dp) == 0 else (
        "data" if batch % _axis_size(mesh, "data") == 0 else None)
    if with_samples:
        return P(None, bax, "model")
    return P(bax, "model")


def to_named(tree_of_specs: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def validate_specs(abstract_tree: Any, specs: Any, mesh: Mesh) -> list[str]:
    """Return a list of (path, problem) strings for non-divisible specs."""
    problems: list[str] = []

    def check(name, leaf):
        spec = specs_by_name.get(name)
        return leaf

    flat_specs = {}
    def gather(name, s):
        flat_specs[name] = s
        return s
    tree_map_with_name(gather, specs)
    specs_by_name = flat_specs

    def rule(name, leaf):
        spec = specs_by_name[name]
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axis is not None and dim % _axis_size(mesh, axis) != 0:
                problems.append(f"{name}: dim {dim} not divisible by {axis}")
        return leaf

    tree_map_with_name(rule, abstract_tree)
    return problems
